//! `drlfoam audit` — repo-invariant lint pass for rules clippy can't see.
//!
//! This repo's acceptance bar is *bitwise-identical* learning output, and
//! its data plane is an `unsafe` mmap'd seqlock ring — so two whole
//! classes of bug are invisible to the compiler and to clippy: a memory
//! ordering or `unsafe` contract quietly weakened, and a source of
//! nondeterminism (hash iteration order, wall-clock reads, f32 reduction
//! order) creeping into a module whose output the equivalence tests pin.
//! The audit makes those *crate-specific* invariants mechanical:
//!
//! | rule | scope | requirement |
//! |------|-------|-------------|
//! | `unsafe-safety-comment` | all of `rust/src/**` | every `unsafe` keyword preceded by a `// SAFETY:` comment (same line, or in the comment block above, attributes/blank lines skipped) |
//! | `det-hash-collections`  | determinism-critical modules | no `HashMap`/`HashSet` at all (iteration order is nondeterministic; use `BTreeMap`/sorted `Vec`) |
//! | `det-wall-clock`        | determinism-critical modules | no `Instant::now` / `SystemTime`; even the sanctioned [`crate::util::clock::telemetry_now`] choke point is flagged so each telemetry read needs a justified allowlist entry |
//! | `f32-sum-in-scored-path`| determinism-critical modules | no `.sum::<f32>()` and no untyped `.sum()` (spell the accumulator type; f32 reduction is order-sensitive) |
//! | `wire-tag-coverage`     | `exec/wire.rs` + fuzz corpus | every `wire::Tag` variant has an encode arm, a decode arm, and a `wire_fuzz` corpus case |
//! | `allowlist-stale`       | the allowlist itself | every allowlist entry still suppresses at least one finding |
//!
//! Determinism-critical modules (`cluster/des.rs`, `cluster/planner.rs`,
//! `coordinator/scheduler.rs`, `drl/*`, `env/*`, `cfd/*`, `obs/*`) are
//! the ones whose outputs the bitwise tests compare — or, for `obs/*`,
//! whose *absence of effect* they compare: DES scores, planner rankings,
//! learning columns, policy parameters, environment rewards/observations,
//! the native CFD engine's fields and force histories, and the traced-
//! vs-untraced twin runs of `rust/tests/determinism.rs`.
//!
//! Audited exceptions live in `rust/audit.allow`, one per line:
//!
//! ```text
//! rule-name | rust/src/relative/path.rs | max-count | justification
//! ```
//!
//! An entry suppresses up to `max-count` findings of `rule-name` in that
//! file; more than `max-count` findings reports them ALL (so a new
//! violation can't hide behind an old exception), and an entry that
//! suppresses nothing is itself a finding (`allowlist-stale`) — the
//! allowlist can only ever shrink-or-justify, never rot.
//!
//! The pass is a line-based pseudo-parser, not a rustc plugin: string
//! literals and comments are stripped before pattern checks (so the rule
//! table above, and the audit's own source, don't self-flag), the file
//! walk is sorted, and all state is `BTreeMap` — the audit holds itself
//! to its own determinism rules. Run `drlfoam audit` (text) or
//! `drlfoam audit --format json` (machine-readable, for CI); exit status
//! is the report's [`AuditReport::ok`]. See ARCHITECTURE.md §9.

mod allow;
mod rules;

pub use allow::{AllowEntry, Allowlist};

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::util::json;
use crate::util::json::Json;

/// Where the audit looks: a repo root (the directory holding `rust/src`),
/// the integration-test dir (fuzz-corpus coverage), and an optional
/// allowlist. [`AuditConfig::discover`] builds one from any cwd inside
/// the repo; tests build fixture configs by hand.
pub struct AuditConfig {
    pub root: PathBuf,
    pub tests_dir: PathBuf,
    pub allowlist: Option<PathBuf>,
}

impl AuditConfig {
    /// Config rooted at an explicit repo root, with the conventional
    /// `rust/tests` + `rust/audit.allow` locations (allowlist only if
    /// the file exists).
    pub fn for_root(root: impl Into<PathBuf>) -> AuditConfig {
        let root = root.into();
        let allow = root.join("rust").join("audit.allow");
        AuditConfig {
            tests_dir: root.join("rust").join("tests"),
            allowlist: allow.is_file().then_some(allow),
            root,
        }
    }

    /// Walk up from `start` to the nearest directory containing
    /// `rust/src` — lets `drlfoam audit` run from anywhere in the repo.
    pub fn discover(start: &Path) -> Result<AuditConfig> {
        let start = start
            .canonicalize()
            .with_context(|| format!("resolving audit start dir {}", start.display()))?;
        let mut dir = start.as_path();
        loop {
            if dir.join("rust").join("src").is_dir() {
                return Ok(AuditConfig::for_root(dir));
            }
            dir = match dir.parent() {
                Some(p) => p,
                None => anyhow::bail!(
                    "no repo root (a directory containing rust/src) above {}",
                    start.display()
                ),
            };
        }
    }
}

/// One rule violation at a specific source line.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    pub rule: &'static str,
    /// Root-relative path, forward slashes.
    pub file: String,
    /// 1-based line number (0 = whole-file finding).
    pub line: usize,
    pub message: String,
}

/// Outcome of one audit run.
pub struct AuditReport {
    /// Violations after allowlist suppression, sorted (file, line, rule).
    pub findings: Vec<Finding>,
    /// Findings suppressed by allowlist entries.
    pub suppressed: usize,
    /// Source files scanned.
    pub files_checked: usize,
}

impl AuditReport {
    pub fn ok(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable report (one `file:line: [rule] message` per finding).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            if f.line > 0 {
                let _ = writeln!(out, "{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
            } else {
                let _ = writeln!(out, "{}: [{}] {}", f.file, f.rule, f.message);
            }
        }
        let _ = writeln!(
            out,
            "audit: {} finding(s), {} suppressed by allowlist, {} file(s) checked — {}",
            self.findings.len(),
            self.suppressed,
            self.files_checked,
            if self.ok() { "clean" } else { "FAIL" }
        );
        out
    }

    /// Machine-readable report for CI (`--format json`).
    pub fn to_json(&self) -> String {
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                json::obj(vec![
                    ("rule", json::s(f.rule)),
                    ("file", json::s(&f.file)),
                    ("line", json::num(f.line as f64)),
                    ("message", json::s(&f.message)),
                ])
            })
            .collect();
        json::obj(vec![
            ("ok", Json::Bool(self.ok())),
            ("files_checked", json::num(self.files_checked as f64)),
            ("suppressed", json::num(self.suppressed as f64)),
            ("findings", Json::Arr(findings)),
        ])
        .to_string()
    }
}

/// A scanned source file: raw lines (SAFETY-comment detection needs
/// comments) and code lines (comments + string literals blanked, so
/// pattern rules can't be fooled by prose or fooled *into* firing on it).
pub(crate) struct SourceFile {
    pub rel: String,
    pub raw: Vec<String>,
    pub code: Vec<String>,
}

impl SourceFile {
    pub(crate) fn load(path: &Path, root: &Path) -> Result<SourceFile> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let raw: Vec<String> = text.lines().map(str::to_owned).collect();
        let code = strip_comments_and_strings(&raw);
        Ok(SourceFile { rel, raw, code })
    }

    /// Is this file in the determinism-critical set (outputs pinned by
    /// the bitwise equivalence tests)?
    pub(crate) fn is_det_critical(&self) -> bool {
        matches!(
            self.rel.as_str(),
            "rust/src/cluster/des.rs"
                | "rust/src/cluster/planner.rs"
                | "rust/src/coordinator/scheduler.rs"
        ) || self.rel.starts_with("rust/src/drl/")
            || self.rel.starts_with("rust/src/env/")
            || self.rel.starts_with("rust/src/cfd/")
            || self.rel.starts_with("rust/src/obs/")
    }
}

/// Run every rule over `rust/src/**` under the config's root and apply
/// the allowlist. The report is deterministic: sorted walk, sorted
/// findings, `BTreeMap` state only.
pub fn run(cfg: &AuditConfig) -> Result<AuditReport> {
    let src_root = cfg.root.join("rust").join("src");
    ensure!(
        src_root.is_dir(),
        "audit root {} has no rust/src",
        cfg.root.display()
    );
    let mut paths = Vec::new();
    collect_rs_files(&src_root, &mut paths)?;
    paths.sort();
    let files = paths
        .iter()
        .map(|p| SourceFile::load(p, &cfg.root))
        .collect::<Result<Vec<_>>>()?;

    let mut findings = Vec::new();
    rules::unsafe_safety_comment(&files, &mut findings);
    rules::det_hash_collections(&files, &mut findings);
    rules::det_wall_clock(&files, &mut findings);
    rules::f32_sum_in_scored_path(&files, &mut findings);
    rules::wire_tag_coverage(&files, &cfg.tests_dir, &mut findings)?;

    let mut suppressed = 0;
    if let Some(path) = &cfg.allowlist {
        let allow = Allowlist::load(path)?;
        let (kept, n) = allow.apply(findings, &cfg.root);
        findings = kept;
        suppressed = n;
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(AuditReport {
        findings,
        suppressed,
        files_checked: files.len(),
    })
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("walking {}", dir.display()))?
    {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if matches!(path.extension(), Some(e) if e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Blank out comments (`//…`, `/*…*/`, doc variants) and string/char
/// literals, preserving line structure and column positions (replaced by
/// spaces). Handles escapes in `"…"`, `'x'`/`'\n'` char literals vs
/// lifetimes, and `r"…"`/`r#"…"#` raw strings; block comments, plain
/// strings (Rust string literals include their newlines), and raw
/// strings may all span lines. A pseudo-lexer — good enough for pattern
/// rules, not a real one.
pub(crate) fn strip_comments_and_strings(raw: &[String]) -> Vec<String> {
    #[derive(Clone, Copy, PartialEq)]
    enum Mode {
        Code,
        Block(u32),    // nested /* depth
        Str,           // inside "…", possibly spanning lines
        RawStr(usize), // number of # in the delimiter
    }
    let mut mode = Mode::Code;
    let mut out = Vec::with_capacity(raw.len());
    for line in raw {
        let b: Vec<char> = line.chars().collect();
        let mut o: Vec<char> = Vec::with_capacity(b.len());
        let mut i = 0;
        while i < b.len() {
            match mode {
                Mode::Block(depth) => {
                    if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                        mode = if depth > 1 { Mode::Block(depth - 1) } else { Mode::Code };
                        o.push(' ');
                        o.push(' ');
                        i += 2;
                    } else if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(depth + 1);
                        o.push(' ');
                        o.push(' ');
                        i += 2;
                    } else {
                        o.push(' ');
                        i += 1;
                    }
                }
                Mode::Str => {
                    if b[i] == '\\' {
                        // escape (incl. a trailing `\` escaping the newline)
                        o.push(' ');
                        o.push(' ');
                        i += 2;
                    } else if b[i] == '"' {
                        o.push(' ');
                        i += 1;
                        mode = Mode::Code;
                    } else {
                        o.push(' ');
                        i += 1;
                    }
                }
                Mode::RawStr(hashes) => {
                    if b[i] == '"' && closes_raw(&b, i + 1, hashes) {
                        for _ in 0..=hashes {
                            o.push(' ');
                        }
                        i += 1 + hashes;
                        mode = Mode::Code;
                    } else {
                        o.push(' ');
                        i += 1;
                    }
                }
                Mode::Code => {
                    let c = b[i];
                    if c == '/' && b.get(i + 1) == Some(&'/') {
                        // line comment: blank to end of line
                        while i < b.len() {
                            o.push(' ');
                            i += 1;
                        }
                    } else if c == '/' && b.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(1);
                        o.push(' ');
                        o.push(' ');
                        i += 2;
                    } else if c == 'r'
                        && !prev_is_ident(&b, i)
                        && raw_str_hashes(&b, i + 1).is_some()
                    {
                        let hashes = raw_str_hashes(&b, i + 1).unwrap();
                        for _ in 0..(2 + hashes) {
                            o.push(' ');
                        }
                        i += 2 + hashes; // r, #…#, "
                        mode = Mode::RawStr(hashes);
                    } else if c == '"' {
                        o.push(' ');
                        i += 1;
                        mode = Mode::Str;
                    } else if c == '\'' && is_char_literal(&b, i) {
                        o.push(' ');
                        i += 1;
                        if b.get(i) == Some(&'\\') {
                            o.push(' ');
                            o.push(' ');
                            i += 2;
                        } else {
                            o.push(' ');
                            i += 1;
                        }
                        if b.get(i) == Some(&'\'') {
                            o.push(' ');
                            i += 1;
                        }
                    } else {
                        o.push(c);
                        i += 1;
                    }
                }
            }
        }
        out.push(o.into_iter().collect());
    }
    out
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

/// At `i` (just past an `r`): `#…#"` or `"` starts a raw string; returns
/// the hash count.
fn raw_str_hashes(b: &[char], i: usize) -> Option<usize> {
    let mut n = 0;
    while b.get(i + n) == Some(&'#') {
        n += 1;
    }
    (b.get(i + n) == Some(&'"')).then_some(n)
}

fn closes_raw(b: &[char], i: usize, hashes: usize) -> bool {
    (0..hashes).all(|k| b.get(i + k) == Some(&'#'))
}

/// `'` at `i` starts a char literal (vs a lifetime): `'\…'` or `'x'`.
fn is_char_literal(b: &[char], i: usize) -> bool {
    b.get(i + 1) == Some(&'\\') || b.get(i + 2) == Some(&'\'')
}

/// Does `hay` contain `needle` as a token — i.e. not embedded in a
/// longer identifier on either side? (`unsafe_op_in_unsafe_fn` must not
/// match a search for `unsafe`; `Frame::StepOut` must not satisfy a
/// search for `Frame::Step`.)
pub(crate) fn contains_token(hay: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(off) = hay[from..].find(needle) {
        let start = from + off;
        let end = start + needle.len();
        let pre = hay[..start].chars().next_back();
        let post = hay[end..].chars().next();
        let pre_ok = !matches!(pre, Some(c) if c.is_alphanumeric() || c == '_');
        let post_ok = !matches!(post, Some(c) if c.is_alphanumeric() || c == '_');
        if pre_ok && post_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strip1(s: &str) -> String {
        strip_comments_and_strings(&[s.to_string()]).remove(0)
    }

    #[test]
    fn strips_line_comments_and_strings_preserving_columns() {
        let s = strip1(r#"let x = "Instant::now"; // HashMap here"#);
        assert!(!s.contains("Instant::now"));
        assert!(!s.contains("HashMap"));
        assert!(s.contains("let x ="));
        assert_eq!(s.len(), r#"let x = "Instant::now"; // HashMap here"#.len());
    }

    #[test]
    fn strips_block_comments_across_lines() {
        let lines = vec![
            "let a = 1; /* HashMap".to_string(),
            "still comment */ let b = 2;".to_string(),
        ];
        let out = strip_comments_and_strings(&lines);
        assert!(!out[0].contains("HashMap"));
        assert!(out[1].contains("let b = 2;"));
        assert!(!out[1].contains("still"));
    }

    #[test]
    fn escaped_quotes_and_char_literals_do_not_derail() {
        let s = strip1(r#"let q = "a\"b"; let c = '"'; let l: &'static str = x;"#);
        assert!(s.contains("let c ="));
        assert!(s.contains("&'static str")); // lifetime untouched
        let s2 = strip1(r"let nl = '\n'; HashMap");
        assert!(s2.contains("HashMap")); // code after the char literal survives
    }

    #[test]
    fn plain_strings_spanning_lines_are_blanked() {
        // Rust string literals include their newlines — interior lines
        // must not be mistaken for code (the CLI usage text mentions
        // `unsafe` and rule names mid-string).
        let lines = vec![
            r#"const USAGE: &str = "first line"#.to_string(),
            "  SAFETY comments on every unsafe, HashMap\";".to_string(),
            "let after = 1;".to_string(),
        ];
        let out = strip_comments_and_strings(&lines);
        assert!(!out[1].contains("unsafe"), "{:?}", out[1]);
        assert!(!out[1].contains("HashMap"));
        assert!(out[2].contains("let after = 1;"));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let s = strip1(r##"let r = r#"Instant::now"#; tail()"##);
        assert!(!s.contains("Instant::now"));
        assert!(s.contains("tail()"));
    }

    #[test]
    fn token_matching_respects_identifier_boundaries() {
        assert!(contains_token("unsafe {", "unsafe"));
        assert!(!contains_token("deny(unsafe_op_in_unsafe_fn)", "unsafe"));
        assert!(contains_token("x = Frame::Step,", "Frame::Step"));
        assert!(!contains_token("x = Frame::StepOut,", "Frame::Step"));
    }

    #[test]
    fn discover_walks_up_to_the_repo_root() {
        let root = std::env::temp_dir().join(format!("audit-discover-{}", std::process::id()));
        let deep = root.join("rust").join("src").join("cluster");
        std::fs::create_dir_all(&deep).unwrap();
        let cfg = AuditConfig::discover(&deep).unwrap();
        assert_eq!(
            cfg.root.canonicalize().unwrap(),
            root.canonicalize().unwrap()
        );
        assert!(AuditConfig::discover(std::path::Path::new("/")).is_err());
        let _ = std::fs::remove_dir_all(&root);
    }
}
