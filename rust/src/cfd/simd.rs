//! AVX2 f32x8 twins of the hot row kernels (advection-diffusion and the
//! SOR phase), behind runtime feature detection with the scalar kernels
//! as fallback.
//!
//! Bitwise contract: each lane performs *exactly* the per-element op
//! sequence of the scalar cell helpers in [`super::kernels`] — unaligned
//! loads of the shifted stencils, IEEE add/sub/mul/div (both paths
//! correctly rounded, no FMA contraction, no reassociation), and the
//! masked SOR blend via `cmp_gt` + `blendv` which selects exactly like
//! the scalar `if mask > 0`. Row remainders that don't fill a lane run
//! the scalar helper. `DRLFOAM_FORCE_SCALAR=1` (read once at engine
//! construction) disables the path entirely; outputs are bitwise equal
//! either way (pinned by `rust/tests/cfd_native.rs`).

/// Is the AVX2 fast path usable on this CPU?
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Has the user forced the scalar fallback? (`DRLFOAM_FORCE_SCALAR=1`.)
pub fn force_scalar_env() -> bool {
    std::env::var("DRLFOAM_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false)
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Vector body of [`super::super::kernels::adv_diff_row_scalar`]:
    /// writes `ru_row[i0..]`/`rv_row[i0..]` in f32x8 lanes while a full
    /// lane fits strictly inside the interior columns, returning the
    /// first unprocessed column (caller finishes with the scalar cell
    /// helper).
    ///
    /// SAFETY: caller must ensure AVX2 is available (runtime-detected),
    /// `u`/`v` are `ny*nx` grids with `1 <= j <= ny-2`, and the row
    /// slices hold `nx` elements.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn adv_diff_row(
        u: &[f32],
        v: &[f32],
        ru_row: &mut [f32],
        rv_row: &mut [f32],
        j: usize,
        nx: usize,
        two_h: f32,
        hh: f32,
        nu: f32,
    ) -> usize {
        let r = j * nx;
        let mut i = 1usize;
        // SAFETY: every load/store below touches indices in
        // [r-nx+i, r+nx+i+7] with i+7 <= nx-2, all inside the `ny*nx`
        // grids because 1 <= j <= ny-2; unaligned intrinsics are used
        // throughout, so no alignment requirement exists.
        unsafe {
            let v_two_h = _mm256_set1_ps(two_h);
            let v_hh = _mm256_set1_ps(hh);
            let v_nu = _mm256_set1_ps(nu);
            let v_four = _mm256_set1_ps(4.0);
            while i + 8 <= nx - 1 {
                let uc = _mm256_loadu_ps(u.as_ptr().add(r + i));
                let ue = _mm256_loadu_ps(u.as_ptr().add(r + i + 1));
                let uw = _mm256_loadu_ps(u.as_ptr().add(r + i - 1));
                let un = _mm256_loadu_ps(u.as_ptr().add(r + nx + i));
                let us = _mm256_loadu_ps(u.as_ptr().add(r - nx + i));
                let vc = _mm256_loadu_ps(v.as_ptr().add(r + i));
                let ve = _mm256_loadu_ps(v.as_ptr().add(r + i + 1));
                let vw = _mm256_loadu_ps(v.as_ptr().add(r + i - 1));
                let vn = _mm256_loadu_ps(v.as_ptr().add(r + nx + i));
                let vs = _mm256_loadu_ps(v.as_ptr().add(r - nx + i));

                let dudx = _mm256_div_ps(_mm256_sub_ps(ue, uw), v_two_h);
                let dudy = _mm256_div_ps(_mm256_sub_ps(un, us), v_two_h);
                let dvdx = _mm256_div_ps(_mm256_sub_ps(ve, vw), v_two_h);
                let dvdy = _mm256_div_ps(_mm256_sub_ps(vn, vs), v_two_h);
                // (((e+w)+n)+s - 4c) / hh — same association as scalar.
                let su = _mm256_add_ps(_mm256_add_ps(_mm256_add_ps(ue, uw), un), us);
                let sv = _mm256_add_ps(_mm256_add_ps(_mm256_add_ps(ve, vw), vn), vs);
                let lap_u =
                    _mm256_div_ps(_mm256_sub_ps(su, _mm256_mul_ps(v_four, uc)), v_hh);
                let lap_v =
                    _mm256_div_ps(_mm256_sub_ps(sv, _mm256_mul_ps(v_four, vc)), v_hh);
                // nu*lap - (c_u*dqdx + c_v*dqdy), matching the scalar cell.
                let adv_u =
                    _mm256_add_ps(_mm256_mul_ps(uc, dudx), _mm256_mul_ps(vc, dudy));
                let adv_v =
                    _mm256_add_ps(_mm256_mul_ps(uc, dvdx), _mm256_mul_ps(vc, dvdy));
                let ru = _mm256_sub_ps(_mm256_mul_ps(v_nu, lap_u), adv_u);
                let rv = _mm256_sub_ps(_mm256_mul_ps(v_nu, lap_v), adv_v);
                _mm256_storeu_ps(ru_row.as_mut_ptr().add(i), ru);
                _mm256_storeu_ps(rv_row.as_mut_ptr().add(i), rv);
                i += 8;
            }
        }
        i
    }

    /// Vector body of the SOR phase row: masked red/black update of
    /// `dst_row` from the `src` snapshot, lanes `i0..` while a full lane
    /// fits in the remap-free column range `[2, nx-2)`; returns the first
    /// unprocessed column.
    ///
    /// SAFETY: caller must ensure AVX2 is available, `src`/`rhs` are
    /// `ny*nx` grids, `jn`/`js` are valid (remapped) row indices, and
    /// `dst_row`/`mask` hold `nx` elements.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn sor_phase_row(
        src: &[f32],
        dst_row: &mut [f32],
        rhs: &[f32],
        mask: &[f32],
        j: usize,
        jn: usize,
        js: usize,
        nx: usize,
        hh: f32,
        omega: f32,
        one_minus_omega: f32,
    ) -> usize {
        let (rm, rn, rs) = (j * nx, jn * nx, js * nx);
        let mut i = 2usize;
        // SAFETY: lanes cover columns [i, i+7] with i+7 <= nx-3 (loop
        // bound), so the shifted loads stay inside rows j/jn/js of the
        // `ny*nx` grids; unaligned intrinsics throughout.
        unsafe {
            let v_q = _mm256_set1_ps(0.25);
            let v_hh = _mm256_set1_ps(hh);
            let v_om = _mm256_set1_ps(omega);
            let v_1mo = _mm256_set1_ps(one_minus_omega);
            let v_zero = _mm256_setzero_ps();
            while i + 8 <= nx - 2 {
                let c = _mm256_loadu_ps(src.as_ptr().add(rm + i));
                let e = _mm256_loadu_ps(src.as_ptr().add(rm + i + 1));
                let w = _mm256_loadu_ps(src.as_ptr().add(rm + i - 1));
                let n = _mm256_loadu_ps(src.as_ptr().add(rn + i));
                let s = _mm256_loadu_ps(src.as_ptr().add(rs + i));
                let rh = _mm256_loadu_ps(rhs.as_ptr().add(rm + i));
                let m = _mm256_loadu_ps(mask.as_ptr().add(i));
                // gs = 0.25*((((e+w)+n)+s) - hh*rhs), scalar association.
                let sum = _mm256_add_ps(_mm256_add_ps(_mm256_add_ps(e, w), n), s);
                let gs = _mm256_mul_ps(v_q, _mm256_sub_ps(sum, _mm256_mul_ps(v_hh, rh)));
                let newv =
                    _mm256_add_ps(_mm256_mul_ps(v_1mo, c), _mm256_mul_ps(v_om, gs));
                // mask > 0 ? newv : c — identical to the scalar branch.
                let sel = _mm256_cmp_ps::<_CMP_GT_OQ>(m, v_zero);
                let out = _mm256_blendv_ps(c, newv, sel);
                _mm256_storeu_ps(dst_row.as_mut_ptr().add(i), out);
                i += 8;
            }
        }
        i
    }
}

#[cfg(target_arch = "x86_64")]
pub use avx2::{adv_diff_row, sor_phase_row};

// Non-x86_64 stubs: `avx2_available()` is false there, so these are
// unreachable; they exist only to keep the dispatch sites compiling.
#[cfg(not(target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
pub unsafe fn adv_diff_row(
    _u: &[f32],
    _v: &[f32],
    _ru_row: &mut [f32],
    _rv_row: &mut [f32],
    _j: usize,
    _nx: usize,
    _two_h: f32,
    _hh: f32,
    _nu: f32,
) -> usize {
    unreachable!("SIMD path dispatched without AVX2")
}

#[cfg(not(target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
pub unsafe fn sor_phase_row(
    _src: &[f32],
    _dst_row: &mut [f32],
    _rhs: &[f32],
    _mask: &[f32],
    _j: usize,
    _jn: usize,
    _js: usize,
    _nx: usize,
    _hh: f32,
    _omega: f32,
    _one_minus_omega: f32,
) -> usize {
    unreachable!("SIMD path dispatched without AVX2")
}
