//! Scalar stencil kernels, boundary conditions, and fixed-order
//! reductions — the op-order reference the SIMD twins in [`super::simd`]
//! must match bitwise.
//!
//! Array convention (same as `python/compile/kernels/ref.py`): fields are
//! `(ny, nx)` f32 row-major, row j = y index, column i = x index. Rows/
//! columns 0 and ny-1/nx-1 are boundary cells owned by the BC routines;
//! stencils only read them and only update the interior. Every kernel
//! spells its f32 evaluation order explicitly (and the SIMD path repeats
//! it lane-wise), so scalar == SIMD == threaded holds bitwise — see
//! ARCHITECTURE.md §10.

/// Inlet Dirichlet (parabolic), outlet zero-gradient, no-slip walls.
/// Write order matters for the corners (rows last), mirroring
/// `cfd.py::apply_vel_bcs`.
pub fn apply_vel_bcs(u: &mut [f32], v: &mut [f32], u_in: &[f32], ny: usize, nx: usize) {
    for j in 0..ny {
        u[j * nx] = u_in[j];
        v[j * nx] = 0.0;
        u[j * nx + nx - 1] = u[j * nx + nx - 2];
        v[j * nx + nx - 1] = v[j * nx + nx - 2];
    }
    for i in 0..nx {
        u[i] = 0.0;
        u[(ny - 1) * nx + i] = 0.0;
        v[i] = 0.0;
        v[(ny - 1) * nx + i] = 0.0;
    }
}

/// Neumann at inlet/walls, Dirichlet p=0 at the outlet. Write order is
/// load-bearing (col 0 first, outlet column last), mirroring
/// `cfd.py::apply_pressure_bcs`.
pub fn apply_pressure_bcs(p: &mut [f32], ny: usize, nx: usize) {
    for j in 0..ny {
        p[j * nx] = p[j * nx + 1];
    }
    for i in 0..nx {
        p[i] = p[nx + i];
        p[(ny - 1) * nx + i] = p[(ny - 2) * nx + i];
    }
    for j in 0..ny {
        p[j * nx + nx - 1] = 0.0;
    }
}

/// Scalar advection-diffusion RHS for one interior row:
/// `r = -q*dqdx - w*dqdy + nu*lap(q)` with central differences, written
/// for columns `i0..nx-1` of row j (boundary reads hit materialized BC
/// values, so no remapping is needed). `i0 = 1` covers the whole row;
/// the SIMD dispatch passes the first column its lanes did not fill.
#[allow(clippy::too_many_arguments)]
pub fn adv_diff_row_scalar(
    u: &[f32],
    v: &[f32],
    ru_row: &mut [f32],
    rv_row: &mut [f32],
    j: usize,
    i0: usize,
    nx: usize,
    two_h: f32,
    hh: f32,
    nu: f32,
) {
    let r = j * nx;
    for i in i0..nx - 1 {
        let (uc, vc) = (u[r + i], v[r + i]);
        let (ue, uw, un, us) = (u[r + i + 1], u[r + i - 1], u[r + nx + i], u[r - nx + i]);
        let (ve, vw, vn, vs) = (v[r + i + 1], v[r + i - 1], v[r + nx + i], v[r - nx + i]);
        let dudx = (ue - uw) / two_h;
        let dudy = (un - us) / two_h;
        let dvdx = (ve - vw) / two_h;
        let dvdy = (vn - vs) / two_h;
        let lap_u = (((ue + uw) + un + us) - 4.0 * uc) / hh;
        let lap_v = (((ve + vw) + vn + vs) - 4.0 * vc) / hh;
        // Python's `-u*dudx - v*dudy + nu*lap` is bitwise `nu*lap - (a+b)`
        // (negation is exact; see ARCHITECTURE.md §10).
        ru_row[i] = nu * lap_u - (uc * dudx + vc * dudy);
        rv_row[i] = nu * lap_v - (uc * dvdx + vc * dvdy);
    }
}

/// One masked SOR cell — the scalar op-order reference for the f32x8
/// lane in `simd::sor_phase_row`: `gs = 0.25*((((e+w)+n)+s) - hh*rhs)`,
/// then the over-relaxed blend, selected by the checkerboard mask.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn sor_cell(
    c: f32,
    e: f32,
    w: f32,
    n: f32,
    s: f32,
    rhs: f32,
    hh: f32,
    omega: f32,
    one_minus_omega: f32,
    colored: bool,
) -> f32 {
    if !colored {
        return c;
    }
    let gs = 0.25 * ((((e + w) + n) + s) - hh * rhs);
    one_minus_omega * c + omega * gs
}

/// `out = a + c * b` over the interior (boundary cells are re-materialized
/// by the subsequent BC application). Plain mul-then-add, matching the
/// XLA lowering of `a + c*b`.
pub fn axpy_interior(out: &mut [f32], a: &[f32], b: &[f32], c: f32, ny: usize, nx: usize) {
    for j in 1..ny - 1 {
        let r = j * nx;
        for i in 1..nx - 1 {
            out[r + i] = a[r + i] + c * b[r + i];
        }
    }
}

/// Backward-difference divergence scaled by 1/dt (the Poisson RHS):
/// `rhs = ((u - W(u))/h + (v - S(v))/h) / dt` over the interior.
pub fn divergence_rhs(
    rhs: &mut [f32],
    u: &[f32],
    v: &[f32],
    h: f32,
    dt: f32,
    ny: usize,
    nx: usize,
) {
    for j in 1..ny - 1 {
        let r = j * nx;
        for i in 1..nx - 1 {
            let div = (u[r + i] - u[r + i - 1]) / h + (v[r + i] - v[r - nx + i]) / h;
            rhs[r + i] = div / dt;
        }
    }
}

/// Projection correction with the forward-difference pressure gradient:
/// `u = us - dt*(E(p)-p)/h`, `v = vs - dt*(N(p)-p)/h` over the interior.
#[allow(clippy::too_many_arguments)]
pub fn pressure_correct(
    u: &mut [f32],
    v: &mut [f32],
    us: &[f32],
    vs: &[f32],
    p: &[f32],
    h: f32,
    dt: f32,
    ny: usize,
    nx: usize,
) {
    for j in 1..ny - 1 {
        let r = j * nx;
        for i in 1..nx - 1 {
            let gpx = (p[r + i + 1] - p[r + i]) / h;
            let gpy = (p[r + nx + i] - p[r + i]) / h;
            u[r + i] = us[r + i] - dt * gpx;
            v[r + i] = vs[r + i] - dt * gpy;
        }
    }
}

/// Fixed-order pairwise tree sum in f32. Deterministic by construction
/// (the order depends only on `terms.len()`), independent of SIMD path
/// and thread count.
pub fn tree_sum(terms: &mut [f32]) -> f32 {
    let mut n = terms.len();
    if n == 0 {
        return 0.0;
    }
    while n > 1 {
        let half = n / 2;
        for k in 0..half {
            terms[k] = terms[2 * k] + terms[2 * k + 1];
        }
        if n % 2 == 1 {
            terms[half] = terms[n - 1];
        }
        n = half + n % 2;
    }
    terms[0]
}

/// f64 variant of [`tree_sum`] — used for the drag/lift force reductions,
/// which numpy/XLA accumulate in f64 (`.astype(float64)` before the sum)
/// and cast back to f32 afterwards.
pub fn tree_sum_f64(terms: &mut [f64]) -> f64 {
    let mut n = terms.len();
    if n == 0 {
        return 0.0;
    }
    while n > 1 {
        let half = n / 2;
        for k in 0..half {
            terms[k] = terms[2 * k] + terms[2 * k + 1];
        }
        if n % 2 == 1 {
            terms[half] = terms[n - 1];
        }
        n = half + n % 2;
    }
    terms[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_sum_is_a_fixed_order_reduction() {
        let mut a = vec![1.0f32, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(tree_sum(&mut a), 15.0);
        assert_eq!(tree_sum(&mut []), 0.0);
        assert_eq!(tree_sum(&mut [42.0]), 42.0);
        // order pinned so a "refactor" to a serial fold (different
        // rounding) is caught: pairwise keeps the small terms together.
        let mut b = vec![1.0f32, 1.0, 1e8, -1e8];
        let tree = tree_sum(&mut b);
        let serial: f32 = [1.0f32, 1.0, 1e8, -1e8].iter().fold(0.0, |acc, x| acc + x);
        assert_eq!(tree, 2.0);
        assert_eq!(serial, 0.0, "serial fold absorbs the small terms");
        assert_ne!(tree, serial);
    }

    #[test]
    fn pressure_bcs_write_order_matches_python() {
        // 3x3: p[:,0]=p[:,1]; p[0,:]=p[1,:]; p[-1,:]=p[-2,:]; p[:,-1]=0.
        let mut p = vec![9.0, 9.0, 9.0, 5.0, 7.0, 9.0, 9.0, 9.0, 9.0];
        apply_pressure_bcs(&mut p, 3, 3);
        // row1 -> [7,7,0]; row0=row1 (post col-0 fix) -> [7,7,0]; corner
        // p[0,0] must be old p[1,1].
        assert_eq!(p, vec![7.0, 7.0, 0.0, 7.0, 7.0, 0.0, 7.0, 7.0, 0.0]);
    }

    #[test]
    fn vel_bcs_zero_walls_after_outlet_copy() {
        let ny = 3;
        let nx = 4;
        let mut u = vec![1.0f32; ny * nx];
        let mut v = vec![1.0f32; ny * nx];
        let u_in = vec![2.0f32; ny];
        apply_vel_bcs(&mut u, &mut v, &u_in, ny, nx);
        assert_eq!(u[nx], 2.0); // inlet row 1
        assert_eq!(v[nx], 0.0);
        assert_eq!(u[nx + nx - 1], u[nx + nx - 2]); // outlet zero-gradient
        assert!(u[..nx].iter().all(|&x| x == 0.0)); // walls overwrite corners
        assert!(u[(ny - 1) * nx..].iter().all(|&x| x == 0.0));
    }
}
