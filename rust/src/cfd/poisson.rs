//! Panel-tiled two-phase red-black SOR pressure solver.
//!
//! One SOR "sweep" of the reference kernel (`python/compile/cfd.py`:
//! materialize pressure BCs, masked red half-update, masked black
//! half-update) is executed here as **two ping-pong phases** over a pair
//! of buffers: each phase writes every interior cell of the destination
//! from the source snapshot — colored cells get the over-relaxed update,
//! the off-color cells are copied through. Boundary cells are never
//! materialized between phases; stencil reads that would land on them
//! are folded through the closed-form BC accessor (row 0 reads row 1,
//! row ny-1 reads row ny-2, column 0 reads column 1 of the *same* row,
//! column nx-1 reads the outlet Dirichlet 0.0). Because every cell a
//! colored update reads is either itself (the column-0 fold) or the
//! *other* color — frozen during this phase — the scheme is bitwise
//! identical to the sequential masked reference (proven against the
//! numpy twin; see ARCHITECTURE.md §10).
//!
//! That same freeze is what makes the phase embarrassingly parallel:
//! threads own static, contiguous panels of destination rows (assignment
//! depends only on `ny` and the thread count), read the shared source
//! snapshot, and synchronize on a barrier at each phase boundary — the
//! barrier *is* the halo exchange. No location is both written and read
//! within a phase, and each cell's value depends only on the snapshot,
//! so results are bitwise independent of the thread count.

use super::{kernels, simd};
use std::sync::Barrier;

/// Rows per tile. Panels are the partition unit so thread assignments
/// stay cache-friendly contiguous row blocks; the value only shapes the
/// split (never the arithmetic), so it is not determinism-relevant.
const PANEL_ROWS: usize = 8;

/// Static panel partition: interior rows `1..ny-1` in contiguous
/// panel-aligned blocks, one per worker. Depends only on (ny, threads).
fn row_ranges(ny: usize, threads: usize) -> Vec<(usize, usize)> {
    let interior = ny - 2;
    let n_panels = (interior + PANEL_ROWS - 1) / PANEL_ROWS;
    let t = threads.min(n_panels).max(1);
    (0..t)
        .map(|k| {
            let lo = k * n_panels / t;
            let hi = (k + 1) * n_panels / t;
            (1 + lo * PANEL_ROWS, (1 + hi * PANEL_ROWS).min(ny - 1))
        })
        .collect()
}

/// One destination row of one phase: masked update of row `j` from the
/// `src` snapshot. `mask` is the checkerboard pattern for this (row,
/// parity); columns 1 and nx-2 fold the inlet/outlet BC reads, the body
/// reads directly (optionally via the AVX2 lanes).
#[allow(clippy::too_many_arguments)]
fn phase_row(
    src: &[f32],
    dst_row: &mut [f32],
    rhs: &[f32],
    mask: &[f32],
    j: usize,
    ny: usize,
    nx: usize,
    hh: f32,
    omega: f32,
    one_minus_omega: f32,
    use_simd: bool,
) {
    // Vertical BC folds: row 0 mirrors row 1, row ny-1 mirrors row ny-2.
    let jn = if j + 1 == ny - 1 { ny - 2 } else { j + 1 };
    let js = if j == 1 { 1 } else { j - 1 };
    let (rm, rn, rs) = (j * nx, jn * nx, js * nx);

    // i = 1: the west read lands on column 0, which mirrors column 1 —
    // i.e. the cell itself.
    let c = src[rm + 1];
    dst_row[1] = kernels::sor_cell(
        c,
        src[rm + 2],
        c,
        src[rn + 1],
        src[rs + 1],
        rhs[rm + 1],
        hh,
        omega,
        one_minus_omega,
        mask[1] > 0.0,
    );

    // Body columns [2, nx-2): no folds needed in either direction.
    let mut i = 2;
    if use_simd {
        // SAFETY: `use_simd` is only set after runtime AVX2 detection
        // (engine construction); src/rhs are ny*nx grids, jn/js are
        // valid remapped interior rows, dst_row/mask are nx long.
        i = unsafe {
            simd::sor_phase_row(
                src,
                dst_row,
                rhs,
                mask,
                j,
                jn,
                js,
                nx,
                hh,
                omega,
                one_minus_omega,
            )
        };
    }
    while i < nx - 2 {
        dst_row[i] = kernels::sor_cell(
            src[rm + i],
            src[rm + i + 1],
            src[rm + i - 1],
            src[rn + i],
            src[rs + i],
            rhs[rm + i],
            hh,
            omega,
            one_minus_omega,
            mask[i] > 0.0,
        );
        i += 1;
    }

    // i = nx-2: the east read lands on the outlet Dirichlet column (0.0).
    let i = nx - 2;
    dst_row[i] = kernels::sor_cell(
        src[rm + i],
        0.0,
        src[rm + i - 1],
        src[rn + i],
        src[rs + i],
        rhs[rm + i],
        hh,
        omega,
        one_minus_omega,
        mask[i] > 0.0,
    );
}

/// Run `n_sweeps` red/black SOR sweeps on `p` (using `scratch` as the
/// ping-pong partner; its prior contents are irrelevant) and materialize
/// the final pressure BCs. Bitwise invariant across `threads` and
/// `use_simd` — pinned by `rust/tests/cfd_native.rs`.
#[allow(clippy::too_many_arguments)]
pub fn solve(
    p: &mut [f32],
    scratch: &mut [f32],
    rhs: &[f32],
    parity_mask: &[Vec<f32>; 2],
    ny: usize,
    nx: usize,
    hh: f32,
    omega: f32,
    one_minus_omega: f32,
    n_sweeps: usize,
    threads: usize,
    use_simd: bool,
) {
    debug_assert!(ny >= 3 && nx >= 4, "grid too small for the BC folds");
    debug_assert_eq!(p.len(), ny * nx);
    debug_assert_eq!(scratch.len(), ny * nx);
    debug_assert_eq!(rhs.len(), ny * nx);

    let ranges = row_ranges(ny, threads);
    if ranges.len() <= 1 {
        for _ in 0..n_sweeps {
            for j in 1..ny - 1 {
                let mask = &parity_mask[j % 2]; // red: (j+i) even
                phase_row(
                    p,
                    &mut scratch[j * nx..(j + 1) * nx],
                    rhs,
                    mask,
                    j,
                    ny,
                    nx,
                    hh,
                    omega,
                    one_minus_omega,
                    use_simd,
                );
            }
            for j in 1..ny - 1 {
                let mask = &parity_mask[(j + 1) % 2]; // black: (j+i) odd
                phase_row(
                    scratch,
                    &mut p[j * nx..(j + 1) * nx],
                    rhs,
                    mask,
                    j,
                    ny,
                    nx,
                    hh,
                    omega,
                    one_minus_omega,
                    use_simd,
                );
            }
        }
    } else {
        let total = ny * nx;
        let p_addr = p.as_mut_ptr() as usize;
        let s_addr = scratch.as_mut_ptr() as usize;
        let barrier = Barrier::new(ranges.len());
        std::thread::scope(|scope| {
            for &(row_lo, row_hi) in &ranges {
                let barrier = &barrier;
                scope.spawn(move || {
                    for _ in 0..n_sweeps {
                        for (parity, src_addr, dst_addr) in
                            [(0usize, p_addr, s_addr), (1, s_addr, p_addr)]
                        {
                            // SAFETY: during this phase `src` is only
                            // read (every thread writes `dst` rows only)
                            // and the previous phase's writes to it were
                            // sequenced by the barrier below, so a shared
                            // borrow of the whole buffer is sound.
                            let src = unsafe {
                                std::slice::from_raw_parts(src_addr as *const f32, total)
                            };
                            for j in row_lo..row_hi {
                                // SAFETY: row ranges from `row_ranges`
                                // are disjoint across threads and `j` is
                                // in this thread's range, so this is the
                                // only live mutable view of these nx
                                // cells; `dst` and `src` are distinct
                                // buffers.
                                let dst_row = unsafe {
                                    std::slice::from_raw_parts_mut(
                                        (dst_addr as *mut f32).add(j * nx),
                                        nx,
                                    )
                                };
                                let mask = &parity_mask[(j + parity) % 2];
                                phase_row(
                                    src,
                                    dst_row,
                                    rhs,
                                    mask,
                                    j,
                                    ny,
                                    nx,
                                    hh,
                                    omega,
                                    one_minus_omega,
                                    use_simd,
                                );
                            }
                            // The halo exchange: no thread may read this
                            // phase's dst as the next phase's src until
                            // every panel is written.
                            barrier.wait();
                        }
                    }
                });
            }
        });
    }

    kernels::apply_pressure_bcs(p, ny, nx);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_ranges_cover_the_interior_exactly_once() {
        for (ny, threads) in [(24, 1), (24, 3), (48, 4), (98, 16), (10, 64)] {
            let ranges = row_ranges(ny, threads);
            let mut next = 1;
            for &(lo, hi) in &ranges {
                assert_eq!(lo, next, "ny={ny} t={threads}");
                assert!(hi > lo);
                next = hi;
            }
            assert_eq!(next, ny - 1, "ny={ny} t={threads}");
        }
    }

    #[test]
    fn solver_reduces_the_residual_and_is_thread_invariant() {
        // A small but realistic grid: fixed rhs bump, zero initial p.
        let (ny, nx) = (24, 40);
        let parity: [Vec<f32>; 2] = [
            (0..nx).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect(),
            (0..nx).map(|i| if i % 2 == 1 { 1.0 } else { 0.0 }).collect(),
        ];
        let mut rhs = vec![0.0f32; ny * nx];
        rhs[12 * nx + 17] = 1.0;
        rhs[7 * nx + 5] = -0.5;
        let hh = 0.01f32;
        let run = |threads: usize, simd: bool| {
            let mut p = vec![0.0f32; ny * nx];
            let mut s = vec![f32::NAN; ny * nx]; // scratch contents must not matter
            solve(
                &mut p, &mut s, &rhs, &parity, ny, nx, hh, 1.7, 1.0 - 1.7, 40, threads, simd,
            );
            p
        };
        let base = run(1, false);
        assert!(base.iter().all(|x| x.is_finite()));
        assert!(base.iter().any(|&x| x != 0.0));
        // outlet Dirichlet held
        for j in 0..ny {
            assert_eq!(base[j * nx + nx - 1], 0.0);
        }
        for threads in [2, 3, 5, 64] {
            assert_eq!(base, run(threads, false), "threads={threads}");
        }
        if simd::avx2_available() {
            assert_eq!(base, run(1, true), "simd scalar mismatch");
            assert_eq!(base, run(4, true), "simd threaded mismatch");
        }
    }
}
