//! Native CFD engine: the cylinder actuation period in pure Rust.
//!
//! This is the artifact-free twin of the XLA path (`python/compile/cfd.py`
//! lowered to HLO by `aot.py` and executed through `runtime::Executable`).
//! It implements the same Chorin projection substep end-to-end — geometry
//! and mask construction, RK2 central advection-diffusion predictor,
//! red-black SOR pressure projection, immersed-boundary forcing, boundary
//! conditions, and force/probe extraction — so the `cylinder` and
//! `cylinder-re200` scenarios train with no `artifacts/` present.
//!
//! Module map:
//!
//! | module | contents |
//! |--------|----------|
//! | [`geometry`] | masks, jets, parabolic inlet, 149 bilinear probes |
//! | [`kernels`]  | scalar stencils, BCs, fixed-order tree reductions |
//! | [`simd`]     | AVX2 f32x8 twins of the hot row kernels (runtime-detected) |
//! | [`poisson`]  | panel-tiled two-phase red-black SOR, scoped-thread pool |
//! | [`engine`]   | [`NativeEngine`]: the period driver + base-flow development |
//!
//! Determinism contract (pinned by `rust/tests/cfd_native.rs`): the engine
//! output is **bitwise identical** across scalar vs SIMD paths, across
//! thread counts, and across runs. See ARCHITECTURE.md §10 for why each
//! holds (per-element op-order parity, static panel partition with
//! phase-barrier halo exchange, fixed-order typed tree sums).

pub mod engine;
pub mod geometry;
pub mod kernels;
pub mod poisson;
pub mod simd;

pub use engine::{BaseFlow, NativeEngine, PeriodOutput};
pub use geometry::Geometry;

use anyhow::{bail, Result};

/// Number of pressure probes (the policy observation width of the real
/// CFD scenarios; matches `python/compile/configs.py::DrlConfig.n_obs`).
pub const N_PROBES: usize = 149;

/// Hidden width of the Rabault-style policy when the cylinder scenarios
/// run artifact-free (matches `DrlConfig.hidden`; with artifacts the
/// manifest supplies the same value).
pub const NATIVE_HIDDEN: usize = 512;

/// `DrlConfig.action_smoothing_beta` (Eq. 11) for artifact-free runs.
pub const NATIVE_ACTION_BETA: f32 = 0.4;

/// `DrlConfig.reward_lift_penalty` (omega in Eq. 12) for artifact-free runs.
pub const NATIVE_LIFT_PENALTY: f32 = 0.1;

/// Which engine executes the CFD actuation period of the cylinder
/// scenarios: the AOT-compiled XLA artifact, or the native Rust engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CfdBackend {
    /// `Executable::run` over `cfd_period_<variant>.hlo.txt` (requires
    /// `make artifacts`).
    Xla,
    /// The pure-Rust engine in this module (no artifacts needed).
    Native,
}

impl CfdBackend {
    pub fn parse(s: &str) -> Result<CfdBackend> {
        match s.trim().to_ascii_lowercase().as_str() {
            "xla" => Ok(CfdBackend::Xla),
            "native" | "rust" => Ok(CfdBackend::Native),
            other => bail!("unknown CFD backend '{other}' (accepted: xla, native)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CfdBackend::Xla => "xla",
            CfdBackend::Native => "native",
        }
    }
}

/// Grid + solver constants for one CFD variant — the native twin of
/// `python/compile/configs.py::GridConfig` (all lengths in units of the
/// cylinder diameter D; derived quantities reproduce the Python property
/// arithmetic in f64 before any cast to f32).
#[derive(Clone, Debug)]
pub struct GridSpec {
    pub name: String,
    pub ny: usize,
    pub x_up: f64,
    pub x_down: f64,
    pub y_lo: f64,
    pub y_hi: f64,
    pub re: f64,
    pub u_mean: f64,
    pub dt: f64,
    pub substeps: usize,
    pub n_sweeps: usize,
    pub sor_omega: f64,
    pub jet_width_deg: f64,
    pub jet_max: f64,
    pub radius: f64,
    pub base_flow_time: f64,
}

impl GridSpec {
    /// Base spec with the shared Schaefer-benchmark geometry; variant
    /// constructors override the numerics.
    fn base(name: &str, ny: usize) -> GridSpec {
        GridSpec {
            name: name.to_string(),
            ny,
            x_up: 2.0,
            x_down: 20.0,
            y_lo: -2.0,
            y_hi: 2.1,
            re: 100.0,
            u_mean: 1.0,
            dt: 0.005,
            substeps: 10,
            n_sweeps: 50,
            sor_omega: 1.7,
            jet_width_deg: 10.0,
            jet_max: 1.5,
            radius: 0.5,
            base_flow_time: 60.0,
        }
    }

    pub fn height(&self) -> f64 {
        self.y_hi - self.y_lo
    }

    /// Uniform grid spacing (set by ny).
    pub fn h(&self) -> f64 {
        self.height() / self.ny as f64
    }

    pub fn nx(&self) -> usize {
        ((self.x_up + self.x_down) / self.h()).round() as usize
    }

    /// Peak of the parabolic inlet profile (Ubar = 2/3 Um).
    pub fn u_max(&self) -> f64 {
        1.5 * self.u_mean
    }

    pub fn y_center(&self) -> f64 {
        0.5 * (self.y_lo + self.y_hi)
    }

    pub fn period(&self) -> f64 {
        self.dt * self.substeps as f64
    }
}

/// Look up a variant preset by name (the same four presets `aot.py`
/// compiles: small, paper, tiny, re200).
pub fn variant(name: &str) -> Result<GridSpec> {
    let mut s = match name {
        "small" => {
            let mut s = GridSpec::base("small", 48);
            s.n_sweeps = 30;
            s.jet_width_deg = 34.0;
            s
        }
        "paper" => {
            let mut s = GridSpec::base("paper", 96);
            s.dt = 0.002;
            s.substeps = 20;
            s.n_sweeps = 60;
            s.base_flow_time = 80.0;
            s.jet_width_deg = 18.0;
            s
        }
        "tiny" => {
            let mut s = GridSpec::base("tiny", 24);
            s.dt = 0.008;
            s.substeps = 4;
            s.n_sweeps = 30;
            s.base_flow_time = 2.0;
            s.jet_width_deg = 45.0;
            s
        }
        "re200" => {
            let mut s = GridSpec::base("re200", 48);
            s.re = 200.0;
            s.n_sweeps = 30;
            s.base_flow_time = 80.0;
            s.jet_width_deg = 34.0;
            s
        }
        other => bail!(
            "unknown CFD variant '{other}' (native engine knows: small, paper, tiny, re200)"
        ),
    };
    s.name = name.to_string();
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_round_trips() {
        for b in [CfdBackend::Xla, CfdBackend::Native] {
            assert_eq!(CfdBackend::parse(b.name()).unwrap(), b);
        }
        assert_eq!(CfdBackend::parse(" Native ").unwrap(), CfdBackend::Native);
        assert_eq!(CfdBackend::parse("rust").unwrap(), CfdBackend::Native);
        let err = CfdBackend::parse("cuda").unwrap_err().to_string();
        assert!(err.contains("xla") && err.contains("native"), "{err}");
    }

    #[test]
    fn variant_grids_match_the_python_presets() {
        // ny -> nx from configs.py: round(22 / (4.1/ny)).
        for (name, ny, nx, substeps) in [
            ("small", 48, 258, 10),
            ("paper", 96, 515, 20),
            ("tiny", 24, 129, 4),
            ("re200", 48, 258, 10),
        ] {
            let s = variant(name).unwrap();
            assert_eq!((s.ny, s.nx(), s.substeps), (ny, nx, substeps), "{name}");
        }
        assert_eq!(variant("re200").unwrap().re, 200.0);
        assert!(variant("huge").is_err());
    }
}
