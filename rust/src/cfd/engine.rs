//! [`NativeEngine`]: the actuation-period driver — the pure-Rust twin of
//! the XLA `cfd_period_<variant>` executable, plus base-flow development
//! (the twin of `python/compile/aot.py::develop_and_measure`).
//!
//! The substep sequence mirrors `python/compile/cfd.py::make_period_fn`
//! verbatim: velocity BCs -> RK2 advection-diffusion predictor ->
//! immersed-boundary force + jet overwrite -> divergence RHS -> red-black
//! SOR projection -> pressure correction -> second force sample -> solid
//! blend; probes are gathered from the final pressure field. All f32 op
//! orders match the reference (see module docs in [`super::kernels`]);
//! force reductions widen to f64 like numpy's `.astype(float64)` sums.

use super::geometry::Geometry;
use super::{kernels, poisson, simd, GridSpec, N_PROBES};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Everything one actuation period returns to the environment.
pub struct PeriodOutput {
    /// 149 pressure probes from the end-of-period field.
    pub probes: Vec<f32>,
    /// Per-substep drag coefficient history.
    pub cd_hist: Vec<f32>,
    /// Per-substep lift coefficient history.
    pub cl_hist: Vec<f32>,
}

/// Developed base flow + the statistics the manifest normally bakes.
pub struct BaseFlow {
    pub u: Vec<f32>,
    pub v: Vec<f32>,
    pub p: Vec<f32>,
    /// Mean drag over the second half of development (reward baseline).
    pub cd0: f64,
    /// Population std of the per-period mean lift over the tail.
    pub cl0_amplitude: f64,
    /// Per-probe mean over the tail periods (observation normalization).
    pub probe_mean: Vec<f32>,
    /// Per-probe std, floored at 1e-3.
    pub probe_std: Vec<f32>,
}

/// Base flows are pure functions of the variant (bitwise invariant across
/// SIMD path and thread count), so one development run per process is
/// shared by every env instance.
static BASE_FLOW_CACHE: Mutex<BTreeMap<String, Arc<BaseFlow>>> = Mutex::new(BTreeMap::new());

pub struct NativeEngine {
    spec: GridSpec,
    geom: Geometry,
    simd: bool,
    threads: usize,
    // f32 constants, cast from f64 exactly where numpy/XLA cast.
    h32: f32,
    dt32: f32,
    hdt: f32,
    two_h: f32,
    hh: f32,
    nu: f32,
    coef: f32,
    qref: f32,
    omega32: f32,
    one_minus_omega: f32,
    // scratch fields (ny*nx each), reused across substeps
    ru: Vec<f32>,
    rv: Vec<f32>,
    uh: Vec<f32>,
    vh: Vec<f32>,
    us: Vec<f32>,
    vs: Vec<f32>,
    rhs: Vec<f32>,
    p_scratch: Vec<f32>,
    term: Vec<f64>,
}

impl NativeEngine {
    pub fn new(spec: GridSpec, threads: usize, force_scalar: bool) -> NativeEngine {
        let geom = Geometry::build(&spec);
        let total = geom.ny * geom.nx;
        let (h, dt) = (spec.h(), spec.dt);
        NativeEngine {
            simd: !force_scalar && simd::avx2_available(),
            threads: threads.max(1),
            h32: h as f32,
            dt32: dt as f32,
            hdt: (0.5 * dt) as f32,
            two_h: (2.0 * h) as f32,
            hh: (h * h) as f32,
            nu: (1.0 / spec.re) as f32,
            coef: (-(h * h / dt)) as f32,
            qref: (0.5 * spec.u_mean * spec.u_mean * (2.0 * spec.radius)) as f32,
            omega32: spec.sor_omega as f32,
            one_minus_omega: (1.0 - spec.sor_omega) as f32,
            ru: vec![0.0; total],
            rv: vec![0.0; total],
            uh: vec![0.0; total],
            vh: vec![0.0; total],
            us: vec![0.0; total],
            vs: vec![0.0; total],
            rhs: vec![0.0; total],
            p_scratch: vec![0.0; total],
            term: Vec::with_capacity(geom.solid_cells.len()),
            geom,
            spec,
        }
    }

    /// Construct from the process environment: `DRLFOAM_CFD_THREADS`
    /// (default 1) and `DRLFOAM_FORCE_SCALAR=1`.
    pub fn from_env(spec: GridSpec) -> NativeEngine {
        let threads = std::env::var("DRLFOAM_CFD_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or(1);
        NativeEngine::new(spec, threads, simd::force_scalar_env())
    }

    pub fn spec(&self) -> &GridSpec {
        &self.spec
    }

    pub fn geom(&self) -> &Geometry {
        &self.geom
    }

    pub fn simd_active(&self) -> bool {
        self.simd
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// (u, v, p) for an impulsive start.
    pub fn quiescent(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        self.geom.quiescent()
    }

    /// Advection-diffusion RHS for all interior rows, SIMD-dispatched.
    #[allow(clippy::too_many_arguments)]
    fn adv_diff(
        ru: &mut [f32],
        rv: &mut [f32],
        u: &[f32],
        v: &[f32],
        ny: usize,
        nx: usize,
        two_h: f32,
        hh: f32,
        nu: f32,
        use_simd: bool,
    ) {
        for j in 1..ny - 1 {
            let row = j * nx;
            let ru_row = &mut ru[row..row + nx];
            let rv_row = &mut rv[row..row + nx];
            let i0 = if use_simd {
                // SAFETY: `use_simd` is only set after runtime AVX2
                // detection; u/v are ny*nx grids and j is interior.
                unsafe { simd::adv_diff_row(u, v, ru_row, rv_row, j, nx, two_h, hh, nu) }
            } else {
                1
            };
            kernels::adv_diff_row_scalar(u, v, ru_row, rv_row, j, i0, nx, two_h, hh, nu);
        }
    }

    /// `coef * sum(solid * (jet*jet_q - q))`, the immersed-boundary force
    /// sample. Fluid cells contribute exact zeros in the reference sum,
    /// so only solid cells are accumulated; terms widen to f64 (numpy's
    /// `.astype(float64)`) and reduce in fixed tree order.
    fn ib_force(geom: &Geometry, term: &mut Vec<f64>, jet: f32, q: &[f32], jet_q: &[f32]) -> f32 {
        term.clear();
        term.extend(
            geom.solid_cells
                .iter()
                .enumerate()
                .map(|(k, &c)| (jet * jet_q[k] - q[c]) as f64),
        );
        kernels::tree_sum_f64(term) as f32
    }

    /// One projection substep, in place on (u, v, p). Returns (cd, cl).
    fn substep(&mut self, u: &mut [f32], v: &mut [f32], p: &mut [f32], jet: f32) -> (f32, f32) {
        let (ny, nx) = (self.geom.ny, self.geom.nx);
        let g = &self.geom;

        kernels::apply_vel_bcs(u, v, &g.u_in, ny, nx);

        // RK2 predictor: half-step state, then the full step from it.
        Self::adv_diff(
            &mut self.ru, &mut self.rv, u, v, ny, nx, self.two_h, self.hh, self.nu, self.simd,
        );
        kernels::axpy_interior(&mut self.uh, u, &self.ru, self.hdt, ny, nx);
        kernels::axpy_interior(&mut self.vh, v, &self.rv, self.hdt, ny, nx);
        kernels::apply_vel_bcs(&mut self.uh, &mut self.vh, &g.u_in, ny, nx);
        Self::adv_diff(
            &mut self.ru,
            &mut self.rv,
            &self.uh,
            &self.vh,
            ny,
            nx,
            self.two_h,
            self.hh,
            self.nu,
            self.simd,
        );
        kernels::axpy_interior(&mut self.us, u, &self.ru, self.dt32, ny, nx);
        kernels::axpy_interior(&mut self.vs, v, &self.rv, self.dt32, ny, nx);
        kernels::apply_vel_bcs(&mut self.us, &mut self.vs, &g.u_in, ny, nx);

        // First IB force sample, then impose the jet inside the solid.
        let fx1 = self.coef * Self::ib_force(g, &mut self.term, jet, &self.us, &g.jet_u);
        let fy1 = self.coef * Self::ib_force(g, &mut self.term, jet, &self.vs, &g.jet_v);
        for (k, &c) in g.solid_cells.iter().enumerate() {
            self.us[c] = jet * g.jet_u[k];
            self.vs[c] = jet * g.jet_v[k];
        }

        // Projection: Poisson solve on the divergence, then correct.
        kernels::divergence_rhs(&mut self.rhs, &self.us, &self.vs, self.h32, self.dt32, ny, nx);
        poisson::solve(
            p,
            &mut self.p_scratch,
            &self.rhs,
            &g.parity_mask,
            ny,
            nx,
            self.hh,
            self.omega32,
            self.one_minus_omega,
            self.spec.n_sweeps,
            self.threads,
            self.simd,
        );
        kernels::pressure_correct(u, v, &self.us, &self.vs, p, self.h32, self.dt32, ny, nx);
        kernels::apply_vel_bcs(u, v, &g.u_in, ny, nx);

        // Second force sample against the corrected field, then blend.
        let fx2 = self.coef * Self::ib_force(g, &mut self.term, jet, u, &g.jet_u);
        let fy2 = self.coef * Self::ib_force(g, &mut self.term, jet, v, &g.jet_v);
        for (k, &c) in g.solid_cells.iter().enumerate() {
            u[c] = jet * g.jet_u[k];
            v[c] = jet * g.jet_v[k];
        }

        ((fx1 + fx2) / self.qref, (fy1 + fy2) / self.qref)
    }

    /// One actuation period (`substeps` projection substeps at constant
    /// jet amplitude), in place on (u, v, p).
    pub fn period(&mut self, u: &mut [f32], v: &mut [f32], p: &mut [f32], jet: f32) -> PeriodOutput {
        crate::obs::bump("cfd.native_periods", 1);
        let n = self.spec.substeps;
        let mut out = PeriodOutput {
            probes: Vec::with_capacity(N_PROBES),
            cd_hist: Vec::with_capacity(n),
            cl_hist: Vec::with_capacity(n),
        };
        for _ in 0..n {
            let (cd, cl) = self.substep(u, v, p, jet);
            out.cd_hist.push(cd);
            out.cl_hist.push(cl);
        }
        // Probe gather: f32 products, 4-term f64 sum, f32 result — the
        // numpy `(vals*w).astype(float64).sum(axis=1).astype(float32)`.
        for (idx, w) in self.geom.probe_idx.iter().zip(&self.geom.probe_w) {
            let t0 = (p[idx[0]] * w[0]) as f64;
            let t1 = (p[idx[1]] * w[1]) as f64;
            let t2 = (p[idx[2]] * w[2]) as f64;
            let t3 = (p[idx[3]] * w[3]) as f64;
            out.probes.push((((t0 + t1) + t2) + t3) as f32);
        }
        out
    }

    /// Develop the unactuated base flow from quiescent and measure the
    /// reward baseline + probe statistics — the `aot.py` twin: per-period
    /// means in f64, statistics over the second half of development,
    /// probe std floored at 1e-3.
    pub fn develop_base_flow(&mut self) -> BaseFlow {
        let (mut u, mut v, mut p) = self.geom.quiescent();
        let n_periods = ((self.spec.base_flow_time / self.spec.period()).round() as usize).max(1);
        let mut cds = Vec::with_capacity(n_periods);
        let mut cls = Vec::with_capacity(n_periods);
        let mut probes = Vec::with_capacity(n_periods);
        for _ in 0..n_periods {
            let out = self.period(&mut u, &mut v, &mut p, 0.0);
            cds.push(mean_f64(&out.cd_hist));
            cls.push(mean_f64(&out.cl_hist));
            probes.push(out.probes);
        }
        // aot.py: tail = slice(max(1, n//2), None); keep the tail
        // non-empty when development is a single period.
        let tail = if n_periods < 2 { 0 } else { (n_periods / 2).max(1) };
        let cd_tail = &cds[tail..];
        let cl_tail = &cls[tail..];
        let mut probe_mean = Vec::with_capacity(N_PROBES);
        let mut probe_std = Vec::with_capacity(N_PROBES);
        let mut col: Vec<f64> = Vec::new();
        for k in 0..N_PROBES {
            col.clear();
            col.extend(probes[tail..].iter().map(|pr| pr[k] as f64));
            let m = col.iter().sum::<f64>() / col.len() as f64;
            let var = col.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / col.len() as f64;
            probe_mean.push(m as f32);
            probe_std.push(var.sqrt().max(1e-3) as f32);
        }
        let cd0 = cd_tail.iter().sum::<f64>() / cd_tail.len() as f64;
        let cl_m = cl_tail.iter().sum::<f64>() / cl_tail.len() as f64;
        let cl_var =
            cl_tail.iter().map(|x| (x - cl_m) * (x - cl_m)).sum::<f64>() / cl_tail.len() as f64;
        BaseFlow {
            u,
            v,
            p,
            cd0,
            cl0_amplitude: cl_var.sqrt(),
            probe_mean,
            probe_std,
        }
    }

    /// Process-wide cached [`develop_base_flow`], keyed by variant name.
    pub fn cached_base_flow(&mut self) -> Arc<BaseFlow> {
        if let Some(bf) = BASE_FLOW_CACHE.lock().unwrap().get(&self.spec.name) {
            return Arc::clone(bf);
        }
        // Develop outside the lock (minutes-scale on big grids); a racing
        // duplicate is bitwise identical, first insert wins.
        let bf = Arc::new(self.develop_base_flow());
        Arc::clone(
            BASE_FLOW_CACHE
                .lock()
                .unwrap()
                .entry(self.spec.name.clone())
                .or_insert(bf),
        )
    }
}

fn mean_f64(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfd::variant;

    fn run_periods(threads: usize, force_scalar: bool, n: usize) -> (Vec<f32>, PeriodOutput) {
        let mut eng = NativeEngine::new(variant("tiny").unwrap(), threads, force_scalar);
        let (mut u, mut v, mut p) = eng.quiescent();
        let mut last = None;
        for _ in 0..n {
            last = Some(eng.period(&mut u, &mut v, &mut p, 0.05));
        }
        (p, last.unwrap())
    }

    #[test]
    fn period_output_shape_and_finiteness() {
        let (p, out) = run_periods(1, true, 3);
        assert_eq!(out.probes.len(), N_PROBES);
        assert_eq!(out.cd_hist.len(), 4); // tiny substeps
        assert!(p.iter().all(|x| x.is_finite()));
        assert!(out.probes.iter().all(|x| x.is_finite()));
        assert!(out.cd_hist.iter().chain(&out.cl_hist).all(|x| x.is_finite()));
        // An impulsively started confined cylinder drags forward.
        assert!(out.cd_hist.iter().all(|&cd| cd > 0.0), "{:?}", out.cd_hist);
    }

    #[test]
    fn periods_are_bitwise_invariant_across_threads_and_simd() {
        let (p_ref, out_ref) = run_periods(1, true, 2);
        for (threads, force_scalar) in [(3, true), (1, false), (4, false)] {
            let (p, out) = run_periods(threads, force_scalar, 2);
            assert_eq!(p_ref, p, "threads={threads} force_scalar={force_scalar}");
            assert_eq!(out_ref.probes, out.probes);
            assert_eq!(out_ref.cd_hist, out.cd_hist);
            assert_eq!(out_ref.cl_hist, out.cl_hist);
        }
    }
}
