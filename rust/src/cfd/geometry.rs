//! Static geometry for the confined-cylinder benchmark — the native twin
//! of `python/compile/cfd.py::build_geometry` / `probe_positions`.
//!
//! Everything here is computed once per engine: the immersed-boundary
//! solid mask (kept as a sparse cell list — the cylinder covers ~0.5% of
//! the grid), the two synthetic jet velocity profiles on the outermost
//! solid shell (theta = ±90°, parabolic lip profile, antisymmetric so the
//! pair has zero net mass flux), the parabolic inlet profile, the SOR
//! checkerboard row patterns, and the 149-probe bilinear gather table.
//!
//! Scalar derivations follow the Python/numpy dtype flow (f64 arithmetic
//! cast to f32 exactly where numpy casts) so masks and weights agree with
//! the AOT-baked geometry; the native-vs-XLA tolerance test in
//! `rust/tests/cfd_native.rs` holds the composition to that.

use super::{GridSpec, N_PROBES};

/// Precomputed static fields for one [`GridSpec`].
pub struct Geometry {
    pub ny: usize,
    pub nx: usize,
    /// Parabolic inlet profile, one value per row (f32, numpy-cast).
    pub u_in: Vec<f32>,
    /// Linear indices (j * nx + i) of solid cells, row-major order.
    pub solid_cells: Vec<usize>,
    /// Unit-action jet velocity at each solid cell (zero off the lips),
    /// aligned with `solid_cells`.
    pub jet_u: Vec<f32>,
    pub jet_v: Vec<f32>,
    /// SOR checkerboard row patterns: `parity_mask[q][i]` is 1.0 where
    /// `i % 2 == q` (interior column bounds are enforced by loop ranges).
    pub parity_mask: [Vec<f32>; 2],
    /// Bilinear gather corners per probe: linear indices of
    /// (j0,i0), (j0,i0+1), (j0+1,i0), (j0+1,i0+1).
    pub probe_idx: Vec<[usize; 4]>,
    /// Bilinear weights per probe (sum to 1).
    pub probe_w: Vec<[f32; 4]>,
}

/// The 149 pressure-probe positions: two rings around the cylinder, a
/// near-jet ring off the two lips, and a 13x7 wake grid.
pub fn probe_positions() -> Vec<[f64; 2]> {
    let mut pts = Vec::with_capacity(N_PROBES);
    for (r, n) in [(0.75_f64, 24usize), (1.0, 24)] {
        for k in 0..n {
            let th = 2.0 * std::f64::consts::PI * k as f64 / n as f64;
            pts.push([r * th.cos(), r * th.sin()]);
        }
    }
    for base in [75.0_f64, 255.0] {
        for k in 0..5 {
            // linspace(base, base+30, 5) in degrees
            let th = (base + 30.0 * k as f64 / 4.0).to_radians();
            pts.push([0.6 * th.cos(), 0.6 * th.sin()]);
        }
    }
    // wake grid: meshgrid(linspace(1,8,13), linspace(-1.5,1.5,7)), C-order
    for ky in 0..7 {
        let y = -1.5 + 3.0 * ky as f64 / 6.0;
        for kx in 0..13 {
            let x = 1.0 + 7.0 * kx as f64 / 12.0;
            pts.push([x, y]);
        }
    }
    debug_assert_eq!(pts.len(), N_PROBES);
    pts
}

impl Geometry {
    pub fn build(spec: &GridSpec) -> Geometry {
        let (ny, nx, h) = (spec.ny, spec.nx(), spec.h());

        // Cell-centre coordinates, f64 -> f32 (numpy: arange*h astype f32).
        let xc: Vec<f32> = (0..nx)
            .map(|i| (-spec.x_up + (i as f64 + 0.5) * h) as f32)
            .collect();
        let yc: Vec<f32> = (0..ny)
            .map(|j| (spec.y_lo + (j as f64 + 0.5) * h) as f32)
            .collect();

        // Solid mask: r < radius with r in f32 (numpy computes sqrt on the
        // f32 meshgrid), compared against the f64 radius like numpy's
        // f32-array < f64-scalar promotion.
        let is_solid = |j: usize, i: usize| -> bool {
            let (x, y) = (xc[i], yc[j]);
            let r = (x * x + y * y).sqrt();
            (r as f64) < spec.radius
        };

        let mut solid_cells = Vec::new();
        for j in 0..ny {
            for i in 0..nx {
                if is_solid(j, i) {
                    solid_cells.push(j * nx + i);
                }
            }
        }

        // Jet profiles on the outermost solid shell (>=1 fluid 4-neighbour;
        // the cylinder never touches the domain boundary, so neighbour
        // lookups need no wrap handling).
        let half_w = spec.jet_width_deg.to_radians() / 2.0;
        let mut jet_u = vec![0.0f32; solid_cells.len()];
        let mut jet_v = vec![0.0f32; solid_cells.len()];
        for (k, &cell) in solid_cells.iter().enumerate() {
            let (j, i) = (cell / nx, cell % nx);
            if j == 0 || j == ny - 1 || i == 0 || i == nx - 1 {
                // The cylinder never reaches the domain boundary for any
                // preset; skip rather than wrap the neighbour lookup.
                continue;
            }
            let shell = !is_solid(j + 1, i)
                || !is_solid(j - 1, i)
                || !is_solid(j, i + 1)
                || !is_solid(j, i - 1);
            if !shell {
                continue;
            }
            // theta in f32 (numpy arctan2 on the f32 meshgrid), widened to
            // f64 for the arc-distance and lip-profile arithmetic exactly
            // where numpy promotes.
            let theta = (yc[j]).atan2(xc[i]);
            let cos_t = theta.cos(); // f32, like np.cos(f32 array)
            let sin_t = theta.sin();
            let (mut ju, mut jv) = (0.0f64, 0.0f64);
            for (theta0, sign) in [(std::f64::consts::FRAC_PI_2, 1.0f64), (-std::f64::consts::FRAC_PI_2, -1.0)] {
                let dth = theta as f64 - theta0;
                let d = dth.sin().atan2(dth.cos());
                if d.abs() < half_w {
                    let w = 1.0 - (d / half_w) * (d / half_w);
                    ju += sign * w * cos_t as f64;
                    jv += sign * w * sin_t as f64;
                }
            }
            jet_u[k] = ju as f32;
            jet_v[k] = jv as f32;
        }

        // Parabolic inlet (f64 arithmetic, f32 cast — numpy astype).
        let u_in: Vec<f32> = yc
            .iter()
            .map(|&y| {
                let t = (y as f64 - spec.y_center()) / (spec.height() / 2.0);
                (spec.u_max() * (1.0 - t * t)) as f32
            })
            .collect();

        // Checkerboard row patterns for the masked SOR blend.
        let parity_mask = [
            (0..nx).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect(),
            (0..nx).map(|i| if i % 2 == 1 { 1.0 } else { 0.0 }).collect(),
        ];

        // Bilinear probe gather table (cell-centre based, clamped).
        let mut probe_idx = Vec::with_capacity(N_PROBES);
        let mut probe_w = Vec::with_capacity(N_PROBES);
        for [px, py] in probe_positions() {
            let fx = (px as f32 as f64 + spec.x_up) / h - 0.5;
            let fy = (py as f32 as f64 - spec.y_lo) / h - 0.5;
            let i0 = (fx.floor() as i64).clamp(0, nx as i64 - 2) as usize;
            let j0 = (fy.floor() as i64).clamp(0, ny as i64 - 2) as usize;
            let tx = (fx - i0 as f64) as f32;
            let ty = (fy - j0 as f64) as f32;
            probe_idx.push([
                j0 * nx + i0,
                j0 * nx + i0 + 1,
                (j0 + 1) * nx + i0,
                (j0 + 1) * nx + i0 + 1,
            ]);
            probe_w.push([
                (1.0 - tx) * (1.0 - ty),
                tx * (1.0 - ty),
                (1.0 - tx) * ty,
                tx * ty,
            ]);
        }

        Geometry {
            ny,
            nx,
            u_in,
            solid_cells,
            jet_u,
            jet_v,
            parity_mask,
            probe_idx,
            probe_w,
        }
    }

    /// Initial condition: inlet profile everywhere, zeroed inside the
    /// cylinder (impulsive start). Returns (u, v, p).
    pub fn quiescent(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (ny, nx) = (self.ny, self.nx);
        let mut u = vec![0.0f32; ny * nx];
        for j in 0..ny {
            let uj = self.u_in[j];
            for i in 0..nx {
                u[j * nx + i] = uj;
            }
        }
        for &c in &self.solid_cells {
            u[c] = 0.0;
        }
        (u, vec![0.0f32; ny * nx], vec![0.0f32; ny * nx])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfd::variant;

    #[test]
    fn probe_layout_has_149_points_with_unit_weights() {
        assert_eq!(probe_positions().len(), N_PROBES);
        let g = Geometry::build(&variant("tiny").unwrap());
        assert_eq!(g.probe_idx.len(), N_PROBES);
        for w in &g.probe_w {
            let s = ((w[0] as f64 + w[1] as f64) + w[2] as f64) + w[3] as f64;
            assert!((s - 1.0).abs() < 1e-5, "weights {w:?} sum {s}");
        }
    }

    #[test]
    fn masks_match_the_python_geometry() {
        // Counts pinned against python/compile/cfd.py::build_geometry.
        let g = Geometry::build(&variant("tiny").unwrap());
        let area = g.solid_cells.len() as f64 * (4.1 / 24.0) * (4.1 / 24.0);
        assert!(
            (area - std::f64::consts::PI * 0.25).abs() < 0.25,
            "solid area {area}"
        );
        // Antisymmetric jet pair: both lips blow/suck along ±y; the jets
        // carry zero net x-momentum up to grid asymmetry.
        let jv: f64 = g.jet_v.iter().map(|&x| x as f64).sum::<f64>();
        assert!(jv > 0.0, "top jet blows outward, bottom sucks: {jv}");
        let n_jet = g.jet_v.iter().filter(|&&x| x != 0.0).count();
        assert!(n_jet >= 2, "expected jet cells on both lips");
        // Inlet: parabolic, peak near mid-channel, ~0 at the walls.
        let peak = g.u_in.iter().cloned().fold(f32::MIN, f32::max);
        assert!((peak as f64 - 1.5).abs() < 0.01, "u_in peak {peak}");
        assert!(g.u_in[0] < 0.3 && g.u_in[g.ny - 1] < 0.3);
    }

    #[test]
    fn quiescent_state_is_masked_inlet_flow() {
        let g = Geometry::build(&variant("tiny").unwrap());
        let (u, v, p) = g.quiescent();
        assert_eq!(u.len(), g.ny * g.nx);
        assert!(v.iter().all(|&x| x == 0.0) && p.iter().all(|&x| x == 0.0));
        for &c in &g.solid_cells {
            assert_eq!(u[c], 0.0);
        }
        assert_eq!(u[(g.ny / 2) * g.nx], g.u_in[g.ny / 2]);
    }
}
