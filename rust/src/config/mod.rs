//! CLI argument parsing (clap is not vendored offline) and shared run
//! configuration helpers.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed `--key value` / `--flag` command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse, given the set of option names that take a value.
    pub fn parse(argv: &[String], value_opts: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if value_opts.contains(&name) {
                    i += 1;
                    let v = argv
                        .get(i)
                        .with_context(|| format!("--{name} expects a value"))?;
                    out.options.insert(name.to_string(), v.clone());
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not an integer")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not an integer")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not a number")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Parse comma-separated usize list, e.g. "1,2,5".
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .with_context(|| format!("--{key}: bad entry {x:?}"))
                })
                .collect(),
        }
    }
}

/// Standard artifact-dir resolution: --artifacts, else $DRLFOAM_ARTIFACTS,
/// else ./artifacts.
pub fn artifact_dir(args: &Args) -> std::path::PathBuf {
    if let Some(d) = args.get("artifacts") {
        return d.into();
    }
    if let Ok(d) = std::env::var("DRLFOAM_ARTIFACTS") {
        return d.into();
    }
    "artifacts".into()
}

pub fn ensure_positional(args: &Args, n: usize, usage: &str) -> Result<()> {
    if args.positional.len() < n {
        bail!("usage: {usage}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            &sv(&["train", "--envs", "4", "--io=binary", "--quiet", "extra"]),
            &["envs"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("envs"), Some("4"));
        assert_eq!(a.get("io"), Some("binary"));
        assert!(a.has_flag("quiet"));
        assert_eq!(a.usize_or("envs", 1).unwrap(), 4);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_bad_numbers() {
        let a = Args::parse(&sv(&["--envs", "x"]), &["envs"]).unwrap();
        assert!(a.usize_or("envs", 1).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&sv(&["--ranks", "1,2,5"]), &["ranks"]).unwrap();
        assert_eq!(a.usize_list_or("ranks", &[9]).unwrap(), vec![1, 2, 5]);
        assert_eq!(a.usize_list_or("other", &[9]).unwrap(), vec![9]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["--envs"]), &["envs"]).is_err());
    }
}
