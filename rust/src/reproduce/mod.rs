//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md section 6 maps each to its module and bench target).
//!
//! Each function returns the rendered text (also printed by the CLI) and
//! writes a CSV under `out/` so the series can be plotted (the README's
//! "CSV outputs" table documents every schema). Paper artefact -> entry:
//!
//! | entry | paper artefact | CSV |
//! |---|---|---|
//! | [`table1`] | Table I (env x rank grid, baseline I/O) | `table1.csv` |
//! | [`fig7`] | Fig 7 (CFD strong scaling) | `fig7.csv` |
//! | [`fig8`] / [`fig9`] | Figs 8-9 (multi-env / hybrid speedup) | `fig8.csv`, `fig9.csv` |
//! | [`fig10`] | Fig 10 (per-episode breakdown) | `fig10.csv` |
//! | [`table2`] | Table II + Figs 11-12 (I/O strategies) | `table2_fig11_fig12.csv` |
//! | [`fig6`] | Fig 6 (reward convergence, REAL training) | `fig6.csv` |
//! | [`summary`] | the conclusion's headline numbers | `summary.csv` |
//! | [`ablation_async`] / [`sync_sweep`] | future-work barrier axis | `ablation_async.csv`, `sync_sweep.csv` |
//! | [`plan`] | the optimal-config claim, via the planner | `plan.csv` |

use anyhow::{Context, Result};

use crate::cluster::planner::{search, PlannerConfig};
use crate::cluster::{simulate_training, Calibration, MpiScaling, SimConfig};
use crate::coordinator::SyncPolicy;
use crate::io_interface::IoMode;
use crate::metrics::scaling::{efficiency, speedup, ScalingRow};
use crate::metrics::tables::{render_table, write_csv};

pub const TABLE1_ENV_SETS: [(usize, &[usize]); 3] = [
    (5, &[1, 2, 4, 6, 8, 10, 12]),
    (2, &[1, 2, 4, 6, 8, 10, 20, 30]),
    (1, &[1, 2, 4, 6, 8, 10, 20, 30, 40, 50, 60]),
];

pub const EPISODES: usize = 3000;

fn run(calib: &Calibration, envs: usize, ranks: usize, mode: IoMode, seed: u64) -> f64 {
    simulate_training(
        calib,
        &SimConfig {
            n_envs: envs,
            n_ranks: ranks,
            episodes_total: EPISODES,
            io_mode: mode,
            sync: SyncPolicy::Full,
            remote_envs: 0,
            seed,
        },
    )
    .total_s
        / 3600.0
}

/// Table I: multi-environment training statistics for ranks 1, 2, 5,
/// per-set reference. Baseline I/O (the paper's original framework).
pub fn table1(calib: &Calibration, out_dir: &std::path::Path) -> Result<String> {
    let mut rows_txt = Vec::new();
    let mut rows_csv = Vec::new();
    for (ranks, env_counts) in TABLE1_ENV_SETS {
        let t_ref = run(calib, 1, ranks, IoMode::Baseline, 1);
        for &envs in env_counts {
            let t = if envs == 1 {
                t_ref
            } else {
                run(calib, envs, ranks, IoMode::Baseline, 1)
            };
            let row = ScalingRow {
                episodes: EPISODES,
                n_envs: envs,
                n_ranks: ranks,
                total_cpus: envs * ranks,
                duration_h: t,
                speedup: speedup(t_ref, t),
                efficiency_pct: efficiency(t_ref, t, ranks, envs * ranks),
            };
            rows_txt.push(vec![
                row.episodes.to_string(),
                row.n_envs.to_string(),
                row.n_ranks.to_string(),
                row.total_cpus.to_string(),
                format!("{:.1}", row.duration_h),
                format!("{:.1}", row.speedup),
                format!("{:.1}", row.efficiency_pct),
            ]);
            rows_csv.push(row.to_csv());
        }
    }
    write_csv(out_dir.join("table1.csv"), ScalingRow::csv_header(), &rows_csv)?;
    Ok(render_table(
        "Table I: parallel multi-environment training (simulated cluster, baseline I/O)",
        &["episodes", "N_envs", "N_ranks", "N_cpus", "duration (h)", "speedup", "eff (%)"],
        &rows_txt,
    ))
}

/// Fig 7: CFD strong scaling, speedup + efficiency vs N_ranks; the T_1
/// (solver only) and T_100 (episode with exchange) series.
pub fn fig7(calib: &Calibration, out_dir: &std::path::Path) -> Result<String> {
    let solver = MpiScaling::default();
    let ranks = [1usize, 2, 4, 8, 16];
    let mut rows_txt = Vec::new();
    let mut rows_csv = Vec::new();
    // T_100: per-episode cost at n ranks including exchange, relative.
    let ep_io = calib.t_io_cpu_baseline + calib.bytes_baseline / calib.disk_bw;
    let t100_1 = calib.t_period_1rank + ep_io;
    for &n in &ranks {
        let s1 = solver.speedup(n);
        let e1 = 100.0 * solver.efficiency(n);
        let t100_n = calib.t_period_1rank * solver.runtime_frac(n) + ep_io;
        let s100 = t100_1 / t100_n;
        let e100 = 100.0 * s100 / n as f64;
        rows_txt.push(vec![
            n.to_string(),
            format!("{s1:.2}"),
            format!("{e1:.1}"),
            format!("{s100:.2}"),
            format!("{e100:.1}"),
        ]);
        rows_csv.push(format!("{n},{s1:.4},{e1:.2},{s100:.4},{e100:.2}"));
    }
    write_csv(
        out_dir.join("fig7.csv"),
        "n_ranks,speedup_T1,eff_T1_pct,speedup_T100,eff_T100_pct",
        &rows_csv,
    )?;
    Ok(render_table(
        "Fig 7: CFD strong scaling (T_1 = single solver instance, T_100 = full episode)",
        &["N_ranks", "speedup T1", "eff T1 %", "speedup T100", "eff T100 %"],
        &rows_txt,
    ))
}

/// Fig 8: multi-env speedup with per-set reference (same data as Table I).
pub fn fig8(calib: &Calibration, out_dir: &std::path::Path) -> Result<String> {
    let mut rows_txt = Vec::new();
    let mut rows_csv = Vec::new();
    for (ranks, env_counts) in TABLE1_ENV_SETS {
        let t_ref = run(calib, 1, ranks, IoMode::Baseline, 1);
        for &envs in env_counts {
            let t = run(calib, envs, ranks, IoMode::Baseline, 1);
            let s = speedup(t_ref, t);
            rows_txt.push(vec![
                ranks.to_string(),
                envs.to_string(),
                format!("{s:.2}"),
            ]);
            rows_csv.push(format!("{ranks},{envs},{s:.4}"));
        }
    }
    write_csv(out_dir.join("fig8.csv"), "n_ranks,n_envs,speedup", &rows_csv)?;
    Ok(render_table(
        "Fig 8: multi-environment speedup (per-rank-set reference)",
        &["N_ranks", "N_envs", "speedup"],
        &rows_txt,
    ))
}

/// Fig 9: hybrid scaling against total CPUs, global {1,1} reference.
pub fn fig9(calib: &Calibration, out_dir: &std::path::Path) -> Result<String> {
    let t_ref = run(calib, 1, 1, IoMode::Baseline, 1);
    let mut rows_txt = Vec::new();
    let mut rows_csv = Vec::new();
    for (ranks, env_counts) in TABLE1_ENV_SETS {
        for &envs in env_counts {
            let t = run(calib, envs, ranks, IoMode::Baseline, 1);
            let cpus = envs * ranks;
            let s = speedup(t_ref, t);
            let e = efficiency(t_ref, t, 1, cpus);
            rows_txt.push(vec![
                ranks.to_string(),
                envs.to_string(),
                cpus.to_string(),
                format!("{s:.2}"),
                format!("{e:.1}"),
            ]);
            rows_csv.push(format!("{ranks},{envs},{cpus},{s:.4},{e:.2}"));
        }
    }
    write_csv(
        out_dir.join("fig9.csv"),
        "n_ranks,n_envs,total_cpus,speedup,efficiency_pct",
        &rows_csv,
    )?;
    Ok(render_table(
        "Fig 9: hybrid parallelization vs total CPUs (global {ranks=1, envs=1} reference)",
        &["N_ranks", "N_envs", "CPUs", "speedup", "eff (%)"],
        &rows_txt,
    ))
}

/// Fig 10: per-episode time breakdown vs N_envs (single-rank CFD).
pub fn fig10(calib: &Calibration, out_dir: &std::path::Path) -> Result<String> {
    let mut rows_txt = Vec::new();
    let mut rows_csv = Vec::new();
    for envs in [1usize, 10, 20, 30, 40, 50, 60] {
        let r = simulate_training(
            calib,
            &SimConfig {
                n_envs: envs,
                n_ranks: 1,
                episodes_total: EPISODES.min(600 * envs),
                io_mode: IoMode::Baseline,
                sync: SyncPolicy::Full,
                remote_envs: 0,
                seed: 1,
            },
        );
        let b = r.breakdown;
        // the paper's instrumentation folds the exchange stall into "CFD
        // simulation time"; we report both views
        rows_txt.push(vec![
            envs.to_string(),
            format!("{:.1}", b.cfd_s),
            format!("{:.1}", b.io_s),
            format!("{:.1}", b.cfd_s + b.io_s),
            format!("{:.2}", b.policy_s),
            format!("{:.1}", b.update_barrier_s),
            format!("{:.0}", 100.0 * r.disk_utilisation),
        ]);
        rows_csv.push(format!(
            "{envs},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3}",
            b.cfd_s, b.io_s, b.cfd_s + b.io_s, b.policy_s, b.update_barrier_s, r.disk_utilisation
        ));
    }
    write_csv(
        out_dir.join("fig10.csv"),
        "n_envs,cfd_s,io_s,cfd_as_measured_s,policy_s,update_barrier_s,disk_util",
        &rows_csv,
    )?;
    Ok(render_table(
        "Fig 10: per-episode time breakdown (ranks=1, baseline I/O)",
        &["N_envs", "CFD (s)", "I/O (s)", "CFD+I/O (s)", "policy (s)", "update+barrier (s)", "disk %"],
        &rows_txt,
    ))
}

/// Table II + Figs 11/12: the three I/O strategies at ranks=1.
pub fn table2(calib: &Calibration, out_dir: &std::path::Path) -> Result<String> {
    let env_counts = [1usize, 2, 4, 6, 8, 10, 20, 30, 40, 50, 60];
    let mut rows_txt = Vec::new();
    let mut rows_csv = Vec::new();
    let mut refs = std::collections::BTreeMap::new();
    for mode in [IoMode::Baseline, IoMode::InMemory, IoMode::Optimized] {
        refs.insert(mode.name(), run(calib, 1, 1, mode, 1));
    }
    for &envs in &env_counts {
        let tb = run(calib, envs, 1, IoMode::Baseline, 1);
        let td = run(calib, envs, 1, IoMode::InMemory, 1);
        let to = run(calib, envs, 1, IoMode::Optimized, 1);
        let pd = 100.0 * (tb - td) / tb;
        let po = 100.0 * (tb - to) / tb;
        rows_txt.push(vec![
            EPISODES.to_string(),
            envs.to_string(),
            format!("{tb:.1}"),
            format!("{td:.1} ({pd:.0}%)"),
            format!("{to:.1} ({po:.0}%)"),
        ]);
        // per-strategy speedup/efficiency (Figs 11/12 use per-strategy refs)
        let sb = refs["baseline"] / tb;
        let sd = refs["in-memory"] / td;
        let so = refs["optimized"] / to;
        rows_csv.push(format!(
            "{envs},{tb:.4},{td:.4},{to:.4},{sb:.4},{sd:.4},{so:.4},{:.2},{:.2},{:.2}",
            100.0 * sb / envs as f64,
            100.0 * sd / envs as f64,
            100.0 * so / envs as f64
        ));
    }
    write_csv(
        out_dir.join("table2_fig11_fig12.csv"),
        "n_envs,t_baseline_h,t_io_disabled_h,t_optimized_h,speedup_baseline,speedup_disabled,speedup_optimized,eff_baseline_pct,eff_disabled_pct,eff_optimized_pct",
        &rows_csv,
    )?;
    Ok(render_table(
        "Table II: I/O strategies, ranks=1 (relative speedup vs baseline in parens)",
        &["episodes", "N_envs", "T_baseline (h)", "T_io-disabled (h)", "T_optimized (h)"],
        &rows_txt,
    ))
}

/// Headline summary: the paper's conclusion numbers.
pub fn summary(calib: &Calibration, out_dir: &std::path::Path) -> Result<String> {
    let t11 = run(calib, 1, 1, IoMode::Baseline, 1);
    let t60_base = run(calib, 60, 1, IoMode::Baseline, 1);
    let t60_opt = run(calib, 60, 1, IoMode::Optimized, 1);
    let eff_base = efficiency(t11, t60_base, 1, 60);
    let eff_opt = efficiency(t11, t60_opt, 1, 60);
    let txt = format!(
        "Headline (paper -> simulated):\n\
         baseline  60 cores: {:.1} h, speedup {:.1}x, eff {:.1}%   (paper: 7.6 h, 29.6x, 49.3%)\n\
         optimized 60 cores: {:.1} h, speedup {:.1}x, eff {:.1}%   (paper: 4.8 h, ~47x, ~78%)\n\
         single-core baseline: {:.1} h                              (paper: 225.2 h)\n",
        t60_base,
        speedup(t11, t60_base),
        eff_base,
        t60_opt,
        speedup(t11, t60_opt),
        eff_opt,
        t11
    );
    write_csv(
        out_dir.join("summary.csv"),
        "metric,simulated,paper",
        &[
            format!("t_1core_h,{t11:.2},225.2"),
            format!("t_60core_baseline_h,{t60_base:.2},7.6"),
            format!("t_60core_optimized_h,{t60_opt:.2},4.8"),
            format!("speedup_baseline,{:.2},29.6", speedup(t11, t60_base)),
            format!("speedup_optimized,{:.2},47.0", speedup(t11, t60_opt)),
            format!("eff_baseline_pct,{eff_base:.2},49.3"),
            format!("eff_optimized_pct,{eff_opt:.2},78.0"),
        ],
    )?;
    Ok(txt)
}

/// Fig 6: reward-convergence invariance to N_envs — REAL training runs on
/// this machine (not DES): same total episode budget split across 1/2/4
/// environments; the curves should overlap when plotted vs episodes.
pub fn fig6(
    artifact_dir: &std::path::Path,
    out_dir: &std::path::Path,
    budget_episodes: usize,
    horizon: usize,
) -> Result<String> {
    use crate::coordinator::{train, TrainConfig};
    let mut rows_csv = Vec::new();
    let mut rows_txt = Vec::new();
    for n_envs in [1usize, 2, 4] {
        let iterations = (budget_episodes / n_envs).max(1);
        let root = out_dir.join(format!("fig6/envs{n_envs}"));
        let cfg = TrainConfig {
            artifact_dir: artifact_dir.to_path_buf(),
            work_dir: root.join("work"),
            out_dir: root,
            variant: "small".into(),
            n_envs,
            io_mode: IoMode::InMemory,
            horizon,
            iterations,
            epochs: 4,
            seed: 11,
            log_every: 10_000,
            quiet: true,
            ..TrainConfig::default()
        };
        let s = train(&cfg)?;
        for r in &s.log {
            rows_csv.push(format!(
                "{n_envs},{},{},{:.6},{:.6}",
                r.iteration, r.episodes_done, r.mean_reward, r.mean_cd
            ));
        }
        let k = (s.log.len() / 3).max(1);
        let head: f64 = s.log[..k].iter().map(|r| r.mean_reward).sum::<f64>() / k as f64;
        let tail: f64 =
            s.log[s.log.len() - k..].iter().map(|r| r.mean_reward).sum::<f64>() / k as f64;
        rows_txt.push(vec![
            n_envs.to_string(),
            iterations.to_string(),
            format!("{head:+.4}"),
            format!("{tail:+.4}"),
            format!("{:+.4}", tail - head),
        ]);
    }
    write_csv(
        out_dir.join("fig6.csv"),
        "n_envs,iteration,episodes,mean_reward,mean_cd",
        &rows_csv,
    )?;
    Ok(render_table(
        "Fig 6: reward convergence vs N_envs (REAL training, same episode budget)",
        &["N_envs", "iters", "reward (early)", "reward (late)", "delta"],
        &rows_txt,
    ))
}

/// Extension ablation: synchronous (barrier) vs asynchronous (barrier-free)
/// training at cluster scale — the paper's future-work direction, DES.
pub fn ablation_async(calib: &Calibration, out_dir: &std::path::Path) -> Result<String> {
    let mut rows_txt = Vec::new();
    let mut rows_csv = Vec::new();
    for mode in [IoMode::Baseline, IoMode::Optimized] {
        for envs in [1usize, 10, 20, 30, 40, 50, 60] {
            let cfg = SimConfig {
                n_envs: envs,
                n_ranks: 1,
                episodes_total: EPISODES,
                io_mode: mode,
                sync: SyncPolicy::Full,
                remote_envs: 0,
                seed: 1,
            };
            let ts = simulate_training(calib, &cfg).total_s / 3600.0;
            let ta = simulate_training(
                calib,
                &SimConfig {
                    sync: SyncPolicy::Async,
                    ..cfg.clone()
                },
            )
            .total_s
                / 3600.0;
            let gain = 100.0 * (ts - ta) / ts;
            rows_txt.push(vec![
                mode.name().to_string(),
                envs.to_string(),
                format!("{ts:.1}"),
                format!("{ta:.1}"),
                format!("{gain:+.1}%"),
            ]);
            rows_csv.push(format!("{},{envs},{ts:.4},{ta:.4},{gain:.2}", mode.name()));
        }
    }
    write_csv(
        out_dir.join("ablation_async.csv"),
        "io_mode,n_envs,t_sync_h,t_async_h,gain_pct",
        &rows_csv,
    )?;
    Ok(render_table(
        "Extension: synchronous vs asynchronous training (DES, ranks=1)",
        &["I/O", "N_envs", "sync (h)", "async (h)", "async gain"],
        &rows_txt,
    ))
}

/// Extension sweep: the full / partial-barrier / async scheduler axis at
/// cluster scale. Reproduces the Table-I barrier-idle trend as the k/n
/// ratio drops: with I/O optimized, idle time is the dominant remaining
/// loss under the full barrier and shrinks monotonically with k.
pub fn sync_sweep(calib: &Calibration, out_dir: &std::path::Path) -> Result<String> {
    let envs = 60usize;
    let policies = [
        SyncPolicy::Full,
        SyncPolicy::Partial { k: 45 },
        SyncPolicy::Partial { k: 30 },
        SyncPolicy::Partial { k: 15 },
        SyncPolicy::Partial { k: 8 },
        SyncPolicy::Partial { k: 4 },
        SyncPolicy::Partial { k: 2 },
        SyncPolicy::Async,
    ];
    let mut rows_txt = Vec::new();
    let mut rows_csv = Vec::new();
    for mode in [IoMode::Baseline, IoMode::Optimized] {
        // Full is the first policy in the sweep; its run doubles as the
        // gain baseline (the DES is deterministic, no need to rerun it)
        let mut t_full = f64::NAN;
        for sync in policies {
            let r = simulate_training(
                calib,
                &SimConfig {
                    n_envs: envs,
                    n_ranks: 1,
                    episodes_total: EPISODES,
                    io_mode: mode,
                    sync,
                    remote_envs: 0,
                    seed: 1,
                },
            );
            let k = sync.effective_k(envs);
            let t = r.total_s / 3600.0;
            if sync == SyncPolicy::Full {
                t_full = t;
            }
            let gain = 100.0 * (t_full - t) / t_full;
            rows_txt.push(vec![
                mode.name().to_string(),
                sync.name(),
                format!("{:.2}", k as f64 / envs as f64),
                format!("{t:.1}"),
                format!("{:.1}", r.breakdown.barrier_idle_s),
                format!("{:.1}", r.breakdown.update_barrier_s),
                format!("{gain:+.1}%"),
            ]);
            rows_csv.push(format!(
                "{},{},{},{:.4},{t:.4},{:.3},{:.3},{gain:.2}",
                mode.name(),
                sync.name(),
                k,
                k as f64 / envs as f64,
                r.breakdown.barrier_idle_s,
                r.breakdown.update_barrier_s,
            ));
        }
    }
    write_csv(
        out_dir.join("sync_sweep.csv"),
        "io_mode,sync,k,k_over_n,total_h,barrier_idle_s,update_barrier_s,gain_vs_full_pct",
        &rows_csv,
    )?;
    Ok(render_table(
        "Extension: rollout scheduler sweep (DES, 60 envs, ranks=1)",
        &["I/O", "sync", "k/n", "total (h)", "idle (s/round)", "update+idle (s/round)", "gain vs full"],
        &rows_txt,
    ))
}

/// The paper's optimal-config claim, rediscovered by search: the
/// allocation planner (`crate::cluster::planner`) sweeps every feasible
/// `(n_envs, ranks, sync, io)` layout under a 60-core budget and must
/// select the Table-I/II optimum — 60 single-rank environments with the
/// optimized exchange, ~47x speedup at ~78% parallel efficiency against
/// the 225.2 h single-core baseline. Writes the full ranking to
/// `out/plan.csv`.
pub fn plan(calib: &Calibration, out_dir: &std::path::Path) -> Result<String> {
    let mut cfg = PlannerConfig::new(60);
    cfg.episodes_total = EPISODES;
    let set = search(calib, &cfg)?;
    set.write_csv(out_dir.join("plan.csv"))?;
    let best = set.best().context("planner returned no feasible layout")?;
    let mut txt = set.render(12);
    txt.push_str(&format!(
        "\nplanner optimum @60 cores (simulated -> paper):\n\
         layout:   {} envs x {} ranks, io {}, sync {}   (paper: 60 x 1, optimized, sync)\n\
         duration: {:.1} h   speedup {:.1}x   eff {:.1}%          (paper: 4.8 h, ~47x, ~78%)\n",
        best.n_envs,
        best.n_ranks,
        best.io_mode.name(),
        best.sync.name(),
        best.duration_h,
        best.speedup,
        best.efficiency_pct,
    ));
    Ok(txt)
}
