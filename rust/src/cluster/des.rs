//! The discrete-event simulator itself.
//!
//! Entities:
//! * `N_envs` environment processes, each statically assigned `N_ranks`
//!   cores (the paper's allocation: N_total = N_envs x N_ranks, reserved
//!   for the whole run — cores never contend);
//! * one shared disk, a FIFO single server with finite bandwidth (the
//!   resource whose queueing produces the paper's N_envs > 30 cliff);
//! * the master/agent process: serial PPO update at the episode barrier.
//!
//! One training iteration = every env runs `horizon` actuation periods
//! (each period: CFD compute -> action/probe exchange through the disk),
//! then the scheduler's barrier, then the serial update. Per-period CFD
//! times draw lognormal jitter; everything is seeded and reproducible.
//!
//! The barrier is the SAME [`SyncPolicy`] type the live coordinator's
//! scheduler runs (`crate::coordinator::scheduler`), so the
//! measured-small/projected-big chain stays truthful for every policy:
//! * [`SyncPolicy::Full`] — global barrier, serial update, repeat for
//!   `episodes_total / N_envs` iterations (the paper's loop);
//! * [`SyncPolicy::Partial`]`{ k }` — every k-th episode completion
//!   fires an update on the k oldest completions; those envs idle from
//!   completion until the update finishes, stragglers keep running;
//! * [`SyncPolicy::Async`] — one update per completion on a dedicated
//!   master core; envs never wait (bounded-stale parameters).
//!
//! Besides wall time, every run reports [`SimResult::mean_staleness`]
//! with the live scheduler's semantics (updates completed between an
//! episode's dispatch and the update that consumes it), which is the
//! third axis the allocation planner ([`super::planner`]) ranks on.
//!
//! Paper artefacts this module reproduces: Table I absolute durations
//! and the Fig 10 per-episode breakdown (full barrier), Table II /
//! Figs 11–12 via the three [`IoMode`]s, and the barrier-idle trend of
//! `drlfoam reproduce sync` (partial/async).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::cluster::calib::Calibration;
use crate::coordinator::scheduler::SyncPolicy;
use crate::io_interface::IoMode;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub n_envs: usize,
    pub n_ranks: usize,
    pub episodes_total: usize,
    pub io_mode: IoMode,
    /// Rollout scheduler policy, mirrored from the live coordinator
    /// (`--sync full|partial:<k>|async`).
    pub sync: SyncPolicy,
    /// How many of the `n_envs` environments run on a remote host behind
    /// a `drlfoam agent` (the placement TAIL: the planner packs host 0 —
    /// the coordinator's — first, so remote envs are always the highest
    /// indices). Each remote env pays one coordinator↔agent round trip
    /// ([`Calibration::t_net_rtt`], charged twice: action out + probes
    /// back) per actuation period, booked as exchange time.
    pub remote_envs: usize,
    pub seed: u64,
}

/// Aggregate time breakdown (per-episode averages; feeds Fig 10).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimBreakdown {
    /// pure CFD compute per episode (s)
    pub cfd_s: f64,
    /// exchange: cpu serialize/parse + disk service + queue wait (s)
    pub io_s: f64,
    /// policy serving per episode (s)
    pub policy_s: f64,
    /// master update + barrier idle per update round (s)
    pub update_barrier_s: f64,
    /// the pure barrier-idle component of `update_barrier_s`: mean
    /// seconds an env spends waiting for its update round (0 under
    /// [`SyncPolicy::Async`]) — the Table-I loss the partial barrier
    /// trades against staleness
    pub barrier_idle_s: f64,
}

#[derive(Clone, Debug)]
pub struct SimResult {
    pub cfg_envs: usize,
    pub cfg_ranks: usize,
    pub total_cpus: usize,
    /// simulated wall-clock for the whole training run (s)
    pub total_s: f64,
    pub breakdown: SimBreakdown,
    /// disk busy fraction over the run (diagnostic: saturation indicator)
    pub disk_utilisation: f64,
    /// mean parameter-version staleness over all consumed episodes, with
    /// the live scheduler's semantics: how many PPO updates completed
    /// between an episode's dispatch and the update that consumed it.
    /// Identically 0 under [`SyncPolicy::Full`] (on-policy); grows as
    /// the barrier relaxes (≈ `n/k - 1` under [`SyncPolicy::Partial`],
    /// ≈ `n - 1` under [`SyncPolicy::Async`]).
    pub mean_staleness: f64,
    /// Episodes actually simulated. The Full/Async loops round
    /// `episodes_total` UP to a whole number of episodes per env, while
    /// the Partial loop consumes exactly `episodes_total` — consumers
    /// comparing sync policies must make sure the counts match (the
    /// planner does so by scoring every policy of a layout on the same
    /// whole-per-env budget; see `super::planner`).
    pub episodes_run: usize,
}

impl SimResult {
    /// Simulated wall-clock for the whole run, in hours — the unit of
    /// the paper's Table I/II duration columns.
    ///
    /// ```
    /// use drlfoam::cluster::{simulate_training, Calibration, SimConfig};
    /// use drlfoam::coordinator::SyncPolicy;
    /// use drlfoam::io_interface::IoMode;
    ///
    /// let r = simulate_training(
    ///     &Calibration::paper_scale(),
    ///     &SimConfig {
    ///         n_envs: 4,
    ///         n_ranks: 1,
    ///         episodes_total: 8,
    ///         io_mode: IoMode::InMemory,
    ///         sync: SyncPolicy::Full,
    ///         remote_envs: 0,
    ///         seed: 1,
    ///     },
    /// );
    /// assert!((r.total_hours() - r.total_s / 3600.0).abs() < 1e-12);
    /// assert!(r.total_hours() > 0.0);
    /// ```
    pub fn total_hours(&self) -> f64 {
        self.total_s / 3600.0
    }
}

#[derive(Clone, Copy, PartialEq)]
struct Event {
    time: f64,
    env: usize,
    kind: EventKind,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum EventKind {
    /// CFD compute for one period finished -> issue exchange
    ComputeDone,
    /// disk service for this env's exchange finished
    DiskDone,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by time (BinaryHeap is a max-heap)
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.env.cmp(&self.env))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Simulate one full training run under `cfg.sync`; returns totals +
/// breakdown.
pub fn simulate_training(calib: &Calibration, cfg: &SimConfig) -> SimResult {
    match cfg.sync {
        SyncPolicy::Full => simulate_full(calib, cfg),
        SyncPolicy::Partial { .. } => simulate_partial(calib, cfg),
        SyncPolicy::Async => simulate_async(calib, cfg),
    }
}

/// [`SyncPolicy::Full`]: the paper's synchronous iteration (global
/// episode barrier, then the serial update).
fn simulate_full(calib: &Calibration, cfg: &SimConfig) -> SimResult {
    let mut rng = Rng::new(cfg.seed ^ 0xDE5);
    let n_envs = cfg.n_envs.max(1);
    let iterations = cfg.episodes_total.div_ceil(n_envs);
    let horizon = calib.horizon;

    let (bytes, io_cpu) = match cfg.io_mode {
        IoMode::Baseline => (calib.bytes_baseline, calib.t_io_cpu_baseline),
        IoMode::Optimized => (calib.bytes_optimized, calib.t_io_cpu_optimized),
        IoMode::InMemory => (0.0, 0.0),
    };
    let t_period = calib.t_period_1rank * calib.rank_model.period_factor(cfg.n_ranks);
    // inter-node term: the placement tail lives behind an agent and pays
    // one socket round trip per period (action out + probes back)
    let remote = cfg.remote_envs.min(n_envs);
    let net_of = |e: usize| if e >= n_envs - remote { 2.0 * calib.t_net_rtt } else { 0.0 };
    // serial PPO update at the barrier: epochs x minibatches(total samples)
    let samples = n_envs * horizon;
    let minibatches = samples.div_ceil(calib.minibatch);
    let t_update = calib.epochs as f64 * minibatches as f64 * calib.t_update_mb;

    let mut clock = 0.0f64;
    let mut agg = SimBreakdown::default();
    let mut disk_busy = 0.0f64;

    // per-env period jitter: lognormal, mean-corrected
    let sigma = calib.period_jitter;
    let mu_corr = -0.5 * sigma * sigma;
    // per-env EPISODE jitter (see calib.rs: this drives the barrier loss)
    let ep_sigma = calib.episode_jitter;
    let ep_mu_corr = -0.5 * ep_sigma * ep_sigma;

    for _iter in 0..iterations {
        // --- one iteration: all envs start at `clock`
        let mut heap: BinaryHeap<Event> = BinaryHeap::new();
        let mut periods_left = vec![horizon; n_envs];
        let mut env_done_at = vec![clock; n_envs];
        let mut disk_free_at = clock;
        // episode-level slowdown factor per env for this iteration
        let ep_factor: Vec<f64> = (0..n_envs)
            .map(|_| (ep_mu_corr + ep_sigma * rng.normal()).exp())
            .collect();

        for e in 0..n_envs {
            let jit = ep_factor[e] * (mu_corr + sigma * rng.normal()).exp();
            let dt = (t_period + calib.t_policy) * jit + net_of(e);
            agg.cfd_s += t_period * jit;
            agg.policy_s += calib.t_policy * jit;
            agg.io_s += net_of(e);
            heap.push(Event {
                time: clock + dt,
                env: e,
                kind: EventKind::ComputeDone,
            });
        }

        while let Some(ev) = heap.pop() {
            match ev.kind {
                EventKind::ComputeDone => {
                    if bytes == 0.0 && io_cpu == 0.0 {
                        // I/O-disabled: go straight to the next period
                        finish_period(
                            &mut heap,
                            &mut periods_left,
                            &mut env_done_at,
                            ev.env,
                            ev.time,
                            t_period * ep_factor[ev.env],
                            net_of(ev.env),
                            calib,
                            sigma,
                            mu_corr,
                            &mut rng,
                            &mut agg,
                        );
                    } else {
                        // CPU-side serialize/parse on the env's own cores,
                        // then a FIFO disk request. Because the heap pops
                        // ComputeDone events in time order, assigning the
                        // server in pop order IS arrival-order FIFO.
                        let ready = ev.time + io_cpu;
                        let svc = bytes / calib.disk_bw;
                        let begin = disk_free_at.max(ready);
                        agg.io_s += io_cpu + (begin - ready) + svc;
                        disk_free_at = begin + svc;
                        disk_busy += svc;
                        heap.push(Event {
                            time: disk_free_at,
                            env: ev.env,
                            kind: EventKind::DiskDone,
                        });
                    }
                }
                EventKind::DiskDone => {
                    finish_period(
                        &mut heap,
                        &mut periods_left,
                        &mut env_done_at,
                        ev.env,
                        ev.time,
                        t_period * ep_factor[ev.env],
                        net_of(ev.env),
                        calib,
                        sigma,
                        mu_corr,
                        &mut rng,
                        &mut agg,
                    );
                }
            }
        }

        // barrier: iteration ends when the slowest env finishes
        let barrier_at = env_done_at.iter().copied().fold(clock, f64::max);
        let idle: f64 = env_done_at.iter().map(|&t| barrier_at - t).sum::<f64>()
            / n_envs as f64;
        agg.barrier_idle_s += idle;
        agg.update_barrier_s += idle + t_update;
        clock = barrier_at + t_update;
    }

    let episodes = (iterations * n_envs) as f64;
    SimResult {
        cfg_envs: n_envs,
        cfg_ranks: cfg.n_ranks,
        total_cpus: n_envs * cfg.n_ranks,
        total_s: clock,
        breakdown: SimBreakdown {
            cfd_s: agg.cfd_s / episodes,
            io_s: agg.io_s / episodes,
            policy_s: agg.policy_s / episodes,
            update_barrier_s: agg.update_barrier_s / (iterations as f64),
            barrier_idle_s: agg.barrier_idle_s / (iterations as f64),
        },
        disk_utilisation: disk_busy / clock.max(1e-12),
        // the full barrier consumes every episode in the update that
        // immediately follows it: on-policy, staleness identically 0
        mean_staleness: 0.0,
        episodes_run: iterations * n_envs,
    }
}

#[allow(clippy::too_many_arguments)]
fn finish_period(
    heap: &mut BinaryHeap<Event>,
    periods_left: &mut [usize],
    env_done_at: &mut [f64],
    env: usize,
    now: f64,
    t_period: f64,
    net_s: f64,
    calib: &Calibration,
    sigma: f64,
    mu_corr: f64,
    rng: &mut Rng,
    agg: &mut SimBreakdown,
) {
    periods_left[env] -= 1;
    if periods_left[env] == 0 {
        env_done_at[env] = now;
        return;
    }
    let jit = (mu_corr + sigma * rng.normal()).exp();
    let dt = (t_period + calib.t_policy) * jit + net_s;
    agg.cfd_s += t_period * jit;
    agg.policy_s += calib.t_policy * jit;
    agg.io_s += net_s;
    heap.push(Event {
        time: now + dt,
        env,
        kind: EventKind::ComputeDone,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn cfg(envs: usize, ranks: usize, mode: IoMode) -> SimConfig {
        SimConfig {
            n_envs: envs,
            n_ranks: ranks,
            episodes_total: 300,
            io_mode: mode,
            sync: SyncPolicy::Full,
            remote_envs: 0,
            seed: 42,
        }
    }

    #[test]
    fn deterministic() {
        let c = Calibration::paper_scale();
        let a = simulate_training(&c, &cfg(8, 1, IoMode::Baseline));
        let b = simulate_training(&c, &cfg(8, 1, IoMode::Baseline));
        assert_eq!(a.total_s, b.total_s);
    }

    #[test]
    fn more_envs_is_faster() {
        let c = Calibration::paper_scale();
        let t1 = simulate_training(&c, &cfg(1, 1, IoMode::Baseline)).total_s;
        let t4 = simulate_training(&c, &cfg(4, 1, IoMode::Baseline)).total_s;
        let t8 = simulate_training(&c, &cfg(8, 1, IoMode::Baseline)).total_s;
        assert!(t4 < t1);
        assert!(t8 < t4);
        // sublinear: efficiency < 1
        assert!(t4 > t1 / 4.0);
    }

    #[test]
    fn io_disabled_never_slower() {
        let c = Calibration::paper_scale();
        for envs in [1, 10, 40, 60] {
            let base = simulate_training(&c, &cfg(envs, 1, IoMode::Baseline)).total_s;
            let none = simulate_training(&c, &cfg(envs, 1, IoMode::InMemory)).total_s;
            let opt = simulate_training(&c, &cfg(envs, 1, IoMode::Optimized)).total_s;
            assert!(none <= base, "envs={envs}");
            assert!(opt <= base * 1.001, "envs={envs}");
        }
    }

    #[test]
    fn remote_envs_pay_the_round_trip_only_when_rtt_is_nonzero() {
        let mut c = Calibration::paper_scale();
        let mut conf = cfg(8, 1, IoMode::Optimized);
        let local = simulate_training(&c, &conf);
        // remote placement with a zero RTT is bit-identical (same draws)
        conf.remote_envs = 4;
        let free = simulate_training(&c, &conf);
        assert_eq!(free.total_s, local.total_s);
        assert_eq!(free.breakdown.io_s, local.breakdown.io_s);
        // a real RTT slows the run and lands in the exchange bucket, and
        // the more envs sit behind the agent the larger the term
        c.t_net_rtt = 0.050;
        let remote4 = simulate_training(&c, &conf);
        assert!(remote4.total_s > local.total_s);
        assert!(remote4.breakdown.io_s > local.breakdown.io_s);
        conf.remote_envs = 8;
        let remote8 = simulate_training(&c, &conf);
        assert!(remote8.breakdown.io_s > remote4.breakdown.io_s);
        // async/partial charge the same per-period term
        for sync in [SyncPolicy::Partial { k: 4 }, SyncPolicy::Async] {
            let mut sc = cfg(8, 1, IoMode::Optimized);
            sc.sync = sync;
            let base = simulate_training(&c, &sc).total_s;
            sc.remote_envs = 8;
            assert!(simulate_training(&c, &sc).total_s > base);
        }
    }

    #[test]
    fn disk_saturates_at_many_envs() {
        let c = Calibration::paper_scale();
        let u10 = simulate_training(&c, &cfg(10, 1, IoMode::Baseline)).disk_utilisation;
        let u60 = simulate_training(&c, &cfg(60, 1, IoMode::Baseline)).disk_utilisation;
        assert!(u60 > 0.85, "disk util at 60 envs = {u60}");
        assert!(u10 < 0.5, "disk util at 10 envs = {u10}");
    }

    #[test]
    fn invariants_hold_over_random_configs() {
        let c = Calibration::paper_scale();
        prop::check("DES invariants", 25, |rng| {
            let envs = 1 + rng.below(64);
            let ranks = 1 + rng.below(8);
            let mode = match rng.below(3) {
                0 => IoMode::Baseline,
                1 => IoMode::Optimized,
                _ => IoMode::InMemory,
            };
            let sync = match rng.below(3) {
                0 => SyncPolicy::Full,
                1 => SyncPolicy::Partial { k: 1 + rng.below(envs) },
                _ => SyncPolicy::Async,
            };
            let r = simulate_training(
                &c,
                &SimConfig {
                    n_envs: envs,
                    n_ranks: ranks,
                    episodes_total: 60,
                    io_mode: mode,
                    sync,
                    remote_envs: rng.below(envs + 1),
                    seed: rng.next_u64(),
                },
            );
            if !(r.total_s.is_finite() && r.total_s > 0.0) {
                return Err("non-finite total".into());
            }
            if r.disk_utilisation > 1.0 + 1e-9 {
                return Err(format!("disk util {}", r.disk_utilisation));
            }
            // an episode can never run faster than its pure compute
            let floor = c.t_period_1rank * c.horizon as f64 * 0.5; // jitter slack
            if (r.total_s / (60f64 / envs as f64).ceil()) < floor {
                return Err("iteration faster than compute floor".into());
            }
            Ok(())
        });
    }
}

// ---------------------------------------------------------------------------
// Asynchronous-training variant (the paper's future-work ablation)
// ---------------------------------------------------------------------------

/// [`SyncPolicy::Async`]: environments run episodes back-to-back, and a
/// dedicated master core applies one PPO update per arriving episode
/// (FIFO); environments do NOT wait for updates (bounded-stale
/// parameters, A3C-style). The run ends when the last update completes.
/// Compare with the other policies via `drlfoam reproduce sync`.
fn simulate_async(calib: &Calibration, cfg: &SimConfig) -> SimResult {
    let mut rng = Rng::new(cfg.seed ^ 0xA57);
    let n_envs = cfg.n_envs.max(1);
    let episodes_per_env = cfg.episodes_total.div_ceil(n_envs);
    let horizon = calib.horizon;

    let (bytes, io_cpu) = match cfg.io_mode {
        IoMode::Baseline => (calib.bytes_baseline, calib.t_io_cpu_baseline),
        IoMode::Optimized => (calib.bytes_optimized, calib.t_io_cpu_optimized),
        IoMode::InMemory => (0.0, 0.0),
    };
    let t_period = calib.t_period_1rank * calib.rank_model.period_factor(cfg.n_ranks);
    let remote = cfg.remote_envs.min(n_envs);
    let net_of = |e: usize| if e >= n_envs - remote { 2.0 * calib.t_net_rtt } else { 0.0 };
    // per-episode update (single trajectory): epochs x ceil(horizon/mb)
    let t_update = calib.epochs as f64
        * horizon.div_ceil(calib.minibatch) as f64
        * calib.t_update_mb;

    let sigma = calib.period_jitter;
    let mu_corr = -0.5 * sigma * sigma;
    let ep_sigma = calib.episode_jitter;
    let ep_mu_corr = -0.5 * ep_sigma * ep_sigma;

    let mut agg = SimBreakdown::default();
    let mut disk_busy = 0.0f64;
    let mut disk_free_at = 0.0f64;
    let mut update_free_at = 0.0f64;

    // one global event loop over the whole run: per env, remaining
    // periods of the current episode + remaining episodes
    let mut heap: BinaryHeap<Event> = BinaryHeap::new();
    let mut periods_left = vec![horizon; n_envs];
    let mut episodes_left = vec![episodes_per_env; n_envs];
    let mut ep_factor = vec![1.0f64; n_envs];
    // staleness accounting, live-scheduler semantics: completion times of
    // fired updates (monotone, FIFO master) + the update count each env
    // had seen when its current episode was dispatched
    let mut update_done: Vec<f64> = Vec::new();
    let mut env_version = vec![0usize; n_envs];
    let mut stale_sum = 0u64;

    let mut draw_period = |rng: &mut Rng, agg: &mut SimBreakdown, f: f64, net: f64| -> f64 {
        let jit = f * (mu_corr + sigma * rng.normal()).exp();
        agg.cfd_s += t_period * jit;
        agg.policy_s += calib.t_policy * jit;
        agg.io_s += net;
        (t_period + calib.t_policy) * jit + net
    };

    for e in 0..n_envs {
        ep_factor[e] = (ep_mu_corr + ep_sigma * rng.normal()).exp();
        let dt = draw_period(&mut rng, &mut agg, ep_factor[e], net_of(e));
        heap.push(Event { time: dt, env: e, kind: EventKind::ComputeDone });
    }

    let mut last_update_done = 0.0f64;
    while let Some(ev) = heap.pop() {
        let next_time = match ev.kind {
            EventKind::ComputeDone if bytes > 0.0 || io_cpu > 0.0 => {
                let ready = ev.time + io_cpu;
                let svc = bytes / calib.disk_bw;
                let begin = disk_free_at.max(ready);
                agg.io_s += io_cpu + (begin - ready) + svc;
                disk_free_at = begin + svc;
                disk_busy += svc;
                heap.push(Event { time: disk_free_at, env: ev.env, kind: EventKind::DiskDone });
                continue;
            }
            _ => ev.time,
        };
        // a period (incl. any exchange) finished at next_time
        periods_left[ev.env] -= 1;
        if periods_left[ev.env] == 0 {
            // episode complete: enqueue the update (env does not wait).
            // Its staleness is the number of updates that fired since the
            // episode was dispatched (this one's index minus the dispatch
            // version), exactly the live scheduler's bookkeeping.
            stale_sum += (update_done.len() - env_version[ev.env]) as u64;
            let begin = update_free_at.max(next_time);
            update_free_at = begin + t_update;
            update_done.push(update_free_at);
            last_update_done = last_update_done.max(update_free_at);
            agg.update_barrier_s += t_update;
            episodes_left[ev.env] -= 1;
            if episodes_left[ev.env] == 0 {
                continue;
            }
            periods_left[ev.env] = horizon;
            ep_factor[ev.env] = (ep_mu_corr + ep_sigma * rng.normal()).exp();
            // the env re-dispatches immediately with whatever parameters
            // have been published by now (its own update may still be
            // queued): version = updates completed by next_time
            env_version[ev.env] = update_done.partition_point(|&d| d <= next_time);
        }
        let dt = draw_period(&mut rng, &mut agg, ep_factor[ev.env], net_of(ev.env));
        heap.push(Event { time: next_time + dt, env: ev.env, kind: EventKind::ComputeDone });
    }

    let makespan = last_update_done;
    let episodes = (episodes_per_env * n_envs) as f64;
    SimResult {
        cfg_envs: n_envs,
        cfg_ranks: cfg.n_ranks,
        total_cpus: n_envs * cfg.n_ranks,
        total_s: makespan,
        breakdown: SimBreakdown {
            cfd_s: agg.cfd_s / episodes,
            io_s: agg.io_s / episodes,
            policy_s: agg.policy_s / episodes,
            update_barrier_s: agg.update_barrier_s / episodes,
            barrier_idle_s: 0.0,
        },
        disk_utilisation: disk_busy / makespan.max(1e-12),
        mean_staleness: stale_sum as f64 / episodes.max(1.0),
        episodes_run: episodes_per_env * n_envs,
    }
}

/// [`SyncPolicy::Partial`]: every k-th episode completion fires a PPO
/// update over the k OLDEST completions (FIFO, exactly the live
/// scheduler's drain order); the envs whose episodes are consumed idle
/// from their completion until the update finishes, then re-dispatch
/// with fresh parameters, while the other `n - k` stragglers keep
/// running. Idle time per env is therefore bounded by waiting for
/// `k - 1` peers instead of `n - 1` — the Table-I barrier loss shrinks
/// as `k/n` drops, at the price of bounded staleness.
fn simulate_partial(calib: &Calibration, cfg: &SimConfig) -> SimResult {
    let mut rng = Rng::new(cfg.seed ^ 0x9A7);
    let n_envs = cfg.n_envs.max(1);
    let k = cfg.sync.effective_k(n_envs);
    let total_episodes = cfg.episodes_total.max(1);
    let horizon = calib.horizon;

    let (bytes, io_cpu) = match cfg.io_mode {
        IoMode::Baseline => (calib.bytes_baseline, calib.t_io_cpu_baseline),
        IoMode::Optimized => (calib.bytes_optimized, calib.t_io_cpu_optimized),
        IoMode::InMemory => (0.0, 0.0),
    };
    let t_period = calib.t_period_1rank * calib.rank_model.period_factor(cfg.n_ranks);
    let remote = cfg.remote_envs.min(n_envs);
    let net_of = |e: usize| if e >= n_envs - remote { 2.0 * calib.t_net_rtt } else { 0.0 };
    // one update consumes `take` trajectories (= k except a short final
    // batch): epochs x minibatches(take x horizon), like the live trainer
    let t_update_for = |take: usize| -> f64 {
        calib.epochs as f64
            * (take * horizon).div_ceil(calib.minibatch) as f64
            * calib.t_update_mb
    };

    let sigma = calib.period_jitter;
    let mu_corr = -0.5 * sigma * sigma;
    let ep_sigma = calib.episode_jitter;
    let ep_mu_corr = -0.5 * ep_sigma * ep_sigma;

    let mut agg = SimBreakdown::default();
    let mut disk_busy = 0.0f64;
    let mut disk_free_at = 0.0f64;
    let mut update_free_at = 0.0f64;

    let mut heap: BinaryHeap<Event> = BinaryHeap::new();
    let mut periods_left = vec![horizon; n_envs];
    let mut ep_factor = vec![1.0f64; n_envs];
    // staleness accounting (see simulate_async): fired-update completion
    // times + per-env dispatch versions
    let mut update_done: Vec<f64> = Vec::new();
    let mut env_version = vec![0usize; n_envs];
    let mut stale_sum = 0u64;

    let mut draw_period = |rng: &mut Rng, agg: &mut SimBreakdown, f: f64, net: f64| -> f64 {
        let jit = f * (mu_corr + sigma * rng.normal()).exp();
        agg.cfd_s += t_period * jit;
        agg.policy_s += calib.t_policy * jit;
        agg.io_s += net;
        (t_period + calib.t_policy) * jit + net
    };

    let mut started = n_envs.min(total_episodes);
    for e in 0..started {
        ep_factor[e] = (ep_mu_corr + ep_sigma * rng.normal()).exp();
        let dt = draw_period(&mut rng, &mut agg, ep_factor[e], net_of(e));
        heap.push(Event { time: dt, env: e, kind: EventKind::ComputeDone });
    }

    // completed episodes queue FIFO until an update round consumes them
    let mut pending: Vec<(usize, f64)> = Vec::new();
    let mut consumed = 0usize;
    let mut updates = 0usize;
    let mut clock_end = 0.0f64;

    while let Some(ev) = heap.pop() {
        let next_time = match ev.kind {
            EventKind::ComputeDone if bytes > 0.0 || io_cpu > 0.0 => {
                let ready = ev.time + io_cpu;
                let svc = bytes / calib.disk_bw;
                let begin = disk_free_at.max(ready);
                agg.io_s += io_cpu + (begin - ready) + svc;
                disk_free_at = begin + svc;
                disk_busy += svc;
                heap.push(Event { time: disk_free_at, env: ev.env, kind: EventKind::DiskDone });
                continue;
            }
            _ => ev.time,
        };
        periods_left[ev.env] -= 1;
        if periods_left[ev.env] > 0 {
            let dt = draw_period(&mut rng, &mut agg, ep_factor[ev.env], net_of(ev.env));
            heap.push(Event { time: next_time + dt, env: ev.env, kind: EventKind::ComputeDone });
            continue;
        }
        // episode complete: queue it; full batches fire updates (possibly
        // more than one when the final short batch drains the queue)
        pending.push((ev.env, next_time));
        loop {
            let remaining = total_episodes - consumed;
            let take = k.min(remaining);
            if remaining == 0 || pending.len() < take {
                break;
            }
            let batch: Vec<(usize, f64)> = pending.drain(..take).collect();
            let t_update = t_update_for(take);
            let ready = batch.iter().map(|&(_, t)| t).fold(0.0f64, f64::max);
            let begin = update_free_at.max(ready);
            let done = begin + t_update;
            // each consumed episode is `this update's index - dispatch
            // version` updates stale (0 whenever k == n)
            let u_idx = update_done.len();
            for &(e, _) in &batch {
                stale_sum += (u_idx - env_version[e]) as u64;
            }
            update_done.push(done);
            update_free_at = done;
            clock_end = clock_end.max(done);
            consumed += take;
            updates += 1;
            let idle: f64 = batch.iter().map(|&(_, t)| begin - t).sum::<f64>() / take as f64;
            agg.barrier_idle_s += idle;
            agg.update_barrier_s += idle + t_update;
            // the consumed envs re-dispatch with the fresh parameters
            for &(e, _) in &batch {
                // re-dispatch happens at `done`, when every fired update
                // (including this one) has completed
                env_version[e] = update_done.len();
                if started >= total_episodes {
                    continue;
                }
                started += 1;
                periods_left[e] = horizon;
                ep_factor[e] = (ep_mu_corr + ep_sigma * rng.normal()).exp();
                let dt = draw_period(&mut rng, &mut agg, ep_factor[e], net_of(e));
                heap.push(Event { time: done + dt, env: e, kind: EventKind::ComputeDone });
            }
        }
    }

    let episodes = consumed.max(1) as f64;
    let rounds = updates.max(1) as f64;
    SimResult {
        cfg_envs: n_envs,
        cfg_ranks: cfg.n_ranks,
        total_cpus: n_envs * cfg.n_ranks,
        total_s: clock_end,
        breakdown: SimBreakdown {
            cfd_s: agg.cfd_s / episodes,
            io_s: agg.io_s / episodes,
            policy_s: agg.policy_s / episodes,
            update_barrier_s: agg.update_barrier_s / rounds,
            barrier_idle_s: agg.barrier_idle_s / rounds,
        },
        disk_utilisation: disk_busy / clock_end.max(1e-12),
        mean_staleness: stale_sum as f64 / episodes,
        episodes_run: consumed,
    }
}

#[cfg(test)]
mod async_tests {
    use super::*;

    fn cfg(envs: usize, mode: IoMode) -> SimConfig {
        SimConfig {
            n_envs: envs,
            n_ranks: 1,
            episodes_total: 600,
            io_mode: mode,
            sync: SyncPolicy::Full,
            remote_envs: 0,
            seed: 9,
        }
    }

    fn with_sync(mut c: SimConfig, sync: SyncPolicy) -> SimConfig {
        c.sync = sync;
        c
    }

    #[test]
    fn async_no_slower_than_sync_without_io() {
        let c = Calibration::paper_scale();
        for envs in [4usize, 12, 30, 60] {
            let ac = with_sync(cfg(envs, IoMode::InMemory), SyncPolicy::Async);
            let sync = simulate_training(&c, &cfg(envs, IoMode::InMemory)).total_s;
            let asyn = simulate_training(&c, &ac).total_s;
            assert!(
                asyn <= sync * 1.02,
                "envs={envs}: async {asyn:.0}s vs sync {sync:.0}s"
            );
        }
    }

    #[test]
    fn async_removes_barrier_loss_at_scale() {
        let c = Calibration::paper_scale();
        let envs = 60;
        let ac = with_sync(cfg(envs, IoMode::Optimized), SyncPolicy::Async);
        let sync = simulate_training(&c, &cfg(envs, IoMode::Optimized)).total_s;
        let asyn = simulate_training(&c, &ac).total_s;
        // the sync barrier costs >= 10% at 60 envs (max of 60 lognormals)
        assert!(
            asyn < sync * 0.95,
            "async {asyn:.0}s not meaningfully faster than sync {sync:.0}s"
        );
    }

    #[test]
    fn async_deterministic() {
        let c = Calibration::paper_scale();
        let ac = with_sync(cfg(8, IoMode::Baseline), SyncPolicy::Async);
        let a = simulate_training(&c, &ac).total_s;
        let b = simulate_training(&c, &ac).total_s;
        assert_eq!(a, b);
    }

    #[test]
    fn episodes_run_reports_the_actual_count_per_loop() {
        // Full/Async round the budget up to whole episodes per env;
        // Partial consumes exactly the budget. The planner relies on
        // this field's contract to keep cross-sync comparisons fair.
        let c = Calibration::paper_scale();
        let envs = 7; // 600 / 7 leaves a remainder
        let run = |sync: SyncPolicy| {
            simulate_training(&c, &with_sync(cfg(envs, IoMode::InMemory), sync)).episodes_run
        };
        assert_eq!(run(SyncPolicy::Full), 602); // ceil(600/7) * 7
        assert_eq!(run(SyncPolicy::Async), 602);
        assert_eq!(run(SyncPolicy::Partial { k: 3 }), 600);
    }

    #[test]
    fn staleness_tracks_the_barrier_axis() {
        // Full is on-policy by construction; relaxing the barrier buys
        // wall time at the price of parameter staleness, bounded by the
        // pool size in steady state — the trade the planner ranks on.
        let c = Calibration::paper_scale();
        let envs = 12;
        let stale = |sync: SyncPolicy| {
            simulate_training(&c, &with_sync(cfg(envs, IoMode::InMemory), sync)).mean_staleness
        };
        assert_eq!(stale(SyncPolicy::Full), 0.0);
        let s_partial = stale(SyncPolicy::Partial { k: 6 });
        let s_async = stale(SyncPolicy::Async);
        assert!(s_partial > 0.0, "partial staleness vanished");
        assert!(
            s_async > s_partial,
            "async {s_async:.2} not staler than partial:6 {s_partial:.2}"
        );
        assert!(
            s_async <= (envs + 1) as f64,
            "async staleness {s_async:.2} beyond the pool-size bound"
        );
    }

    #[test]
    fn partial_deterministic_and_dispatched_by_sync_field() {
        let c = Calibration::paper_scale();
        let pc = with_sync(cfg(8, IoMode::Baseline), SyncPolicy::Partial { k: 3 });
        let a = simulate_training(&c, &pc).total_s;
        let b = simulate_training(&c, &pc).total_s;
        assert_eq!(a, b);
        // a different k is a genuinely different schedule
        let d = simulate_training(&c, &with_sync(cfg(8, IoMode::Baseline), SyncPolicy::Partial { k: 6 }));
        assert_ne!(a, d.total_s);
        // the async policy is deterministic through the same entry point
        let e1 = simulate_training(&c, &with_sync(cfg(8, IoMode::Baseline), SyncPolicy::Async));
        let e2 = simulate_training(&c, &with_sync(cfg(8, IoMode::Baseline), SyncPolicy::Async));
        assert_eq!(e1.total_s, e2.total_s);
    }

    #[test]
    fn barrier_idle_shrinks_as_k_drops() {
        // the Table-I trend the sweep reproduces: once I/O is optimized,
        // the barrier idle time falls monotonically with the k/n ratio
        let c = Calibration::paper_scale();
        let envs = 60;
        let idle = |sync: SyncPolicy| {
            simulate_training(&c, &with_sync(cfg(envs, IoMode::Optimized), sync))
                .breakdown
                .barrier_idle_s
        };
        let i_full = idle(SyncPolicy::Full);
        let i_30 = idle(SyncPolicy::Partial { k: 30 });
        let i_5 = idle(SyncPolicy::Partial { k: 5 });
        let i_async = idle(SyncPolicy::Async);
        assert!(i_full > i_30, "full {i_full:.1}s !> partial:30 {i_30:.1}s");
        assert!(i_30 > i_5, "partial:30 {i_30:.1}s !> partial:5 {i_5:.1}s");
        assert!(i_5 > 0.0, "partial:5 idle vanished");
        assert_eq!(i_async, 0.0, "async has no barrier");
    }

    #[test]
    fn partial_total_time_sits_between_full_and_async() {
        let c = Calibration::paper_scale();
        let envs = 60;
        let total = |sync: SyncPolicy| {
            simulate_training(&c, &with_sync(cfg(envs, IoMode::Optimized), sync)).total_s
        };
        let t_full = total(SyncPolicy::Full);
        let t_partial = total(SyncPolicy::Partial { k: 10 });
        let t_async = total(SyncPolicy::Async);
        // partial removes most of the barrier loss (2% slack for jitter)
        assert!(
            t_partial < t_full,
            "partial {t_partial:.0}s not faster than full {t_full:.0}s"
        );
        assert!(
            t_async <= t_partial * 1.02,
            "async {t_async:.0}s slower than partial {t_partial:.0}s"
        );
    }

    #[test]
    fn partial_k_clamped_to_pool_matches_full_shape() {
        // partial:k>=n is a full barrier: same idle magnitude (different
        // rng draw order, so shape-level agreement, not bitwise)
        let c = Calibration::paper_scale();
        let f = simulate_training(&c, &cfg(30, IoMode::Optimized));
        let p = simulate_training(&c, &with_sync(cfg(30, IoMode::Optimized), SyncPolicy::Partial { k: 64 }));
        let rel = (p.total_s - f.total_s).abs() / f.total_s;
        assert!(rel < 0.05, "partial:n {:.0}s vs full {:.0}s (rel {rel:.3})", p.total_s, f.total_s);
        let rel_idle = (p.breakdown.barrier_idle_s - f.breakdown.barrier_idle_s).abs()
            / f.breakdown.barrier_idle_s.max(1e-9);
        assert!(rel_idle < 0.35, "idle {:.2}s vs {:.2}s", p.breakdown.barrier_idle_s, f.breakdown.barrier_idle_s);
    }
}
