//! The discrete-event simulator itself.
//!
//! Entities:
//! * `N_envs` environment processes, each statically assigned `N_ranks`
//!   cores (the paper's allocation: N_total = N_envs x N_ranks, reserved
//!   for the whole run — cores never contend);
//! * one shared disk, a FIFO single server with finite bandwidth (the
//!   resource whose queueing produces the paper's N_envs > 30 cliff);
//! * the master/agent process: serial PPO update at the episode barrier.
//!
//! One training iteration = every env runs `horizon` actuation periods
//! (each period: CFD compute -> action/probe exchange through the disk),
//! then a global barrier, then the serial update. Repeat for
//! `episodes_total / N_envs` iterations. Per-period CFD times draw
//! lognormal jitter; everything is seeded and reproducible.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::cluster::calib::Calibration;
use crate::io_interface::IoMode;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub n_envs: usize,
    pub n_ranks: usize,
    pub episodes_total: usize,
    pub io_mode: IoMode,
    pub seed: u64,
}

/// Aggregate time breakdown (per-episode averages; feeds Fig 10).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimBreakdown {
    /// pure CFD compute per episode (s)
    pub cfd_s: f64,
    /// exchange: cpu serialize/parse + disk service + queue wait (s)
    pub io_s: f64,
    /// policy serving per episode (s)
    pub policy_s: f64,
    /// master update + barrier idle per episode (s)
    pub update_barrier_s: f64,
}

#[derive(Clone, Debug)]
pub struct SimResult {
    pub cfg_envs: usize,
    pub cfg_ranks: usize,
    pub total_cpus: usize,
    /// simulated wall-clock for the whole training run (s)
    pub total_s: f64,
    pub breakdown: SimBreakdown,
    /// disk busy fraction over the run (diagnostic: saturation indicator)
    pub disk_utilisation: f64,
}

impl SimResult {
    pub fn total_hours(&self) -> f64 {
        self.total_s / 3600.0
    }
}

#[derive(Clone, Copy, PartialEq)]
struct Event {
    time: f64,
    env: usize,
    kind: EventKind,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum EventKind {
    /// CFD compute for one period finished -> issue exchange
    ComputeDone,
    /// disk service for this env's exchange finished
    DiskDone,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by time (BinaryHeap is a max-heap)
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.env.cmp(&self.env))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Simulate one full training run; returns totals + breakdown.
pub fn simulate_training(calib: &Calibration, cfg: &SimConfig) -> SimResult {
    let mut rng = Rng::new(cfg.seed ^ 0xDE5);
    let n_envs = cfg.n_envs.max(1);
    let iterations = cfg.episodes_total.div_ceil(n_envs);
    let horizon = calib.horizon;

    let (bytes, io_cpu) = match cfg.io_mode {
        IoMode::Baseline => (calib.bytes_baseline, calib.t_io_cpu_baseline),
        IoMode::Optimized => (calib.bytes_optimized, calib.t_io_cpu_optimized),
        IoMode::InMemory => (0.0, 0.0),
    };
    let t_period = calib.t_period_1rank * calib.rank_model.period_factor(cfg.n_ranks);
    // serial PPO update at the barrier: epochs x minibatches(total samples)
    let samples = n_envs * horizon;
    let minibatches = samples.div_ceil(calib.minibatch);
    let t_update = calib.epochs as f64 * minibatches as f64 * calib.t_update_mb;

    let mut clock = 0.0f64;
    let mut agg = SimBreakdown::default();
    let mut disk_busy = 0.0f64;

    // per-env period jitter: lognormal, mean-corrected
    let sigma = calib.period_jitter;
    let mu_corr = -0.5 * sigma * sigma;
    // per-env EPISODE jitter (see calib.rs: this drives the barrier loss)
    let ep_sigma = calib.episode_jitter;
    let ep_mu_corr = -0.5 * ep_sigma * ep_sigma;

    for _iter in 0..iterations {
        // --- one iteration: all envs start at `clock`
        let mut heap: BinaryHeap<Event> = BinaryHeap::new();
        let mut periods_left = vec![horizon; n_envs];
        let mut env_done_at = vec![clock; n_envs];
        let mut disk_free_at = clock;
        // episode-level slowdown factor per env for this iteration
        let ep_factor: Vec<f64> = (0..n_envs)
            .map(|_| (ep_mu_corr + ep_sigma * rng.normal()).exp())
            .collect();

        for e in 0..n_envs {
            let jit = ep_factor[e] * (mu_corr + sigma * rng.normal()).exp();
            let dt = (t_period + calib.t_policy) * jit;
            agg.cfd_s += t_period * jit;
            agg.policy_s += calib.t_policy * jit;
            heap.push(Event {
                time: clock + dt,
                env: e,
                kind: EventKind::ComputeDone,
            });
        }

        while let Some(ev) = heap.pop() {
            match ev.kind {
                EventKind::ComputeDone => {
                    if bytes == 0.0 && io_cpu == 0.0 {
                        // I/O-disabled: go straight to the next period
                        finish_period(
                            &mut heap,
                            &mut periods_left,
                            &mut env_done_at,
                            ev.env,
                            ev.time,
                            t_period * ep_factor[ev.env],
                            calib,
                            sigma,
                            mu_corr,
                            &mut rng,
                            &mut agg,
                        );
                    } else {
                        // CPU-side serialize/parse on the env's own cores,
                        // then a FIFO disk request. Because the heap pops
                        // ComputeDone events in time order, assigning the
                        // server in pop order IS arrival-order FIFO.
                        let ready = ev.time + io_cpu;
                        let svc = bytes / calib.disk_bw;
                        let begin = disk_free_at.max(ready);
                        agg.io_s += io_cpu + (begin - ready) + svc;
                        disk_free_at = begin + svc;
                        disk_busy += svc;
                        heap.push(Event {
                            time: disk_free_at,
                            env: ev.env,
                            kind: EventKind::DiskDone,
                        });
                    }
                }
                EventKind::DiskDone => {
                    finish_period(
                        &mut heap,
                        &mut periods_left,
                        &mut env_done_at,
                        ev.env,
                        ev.time,
                        t_period * ep_factor[ev.env],
                        calib,
                        sigma,
                        mu_corr,
                        &mut rng,
                        &mut agg,
                    );
                }
            }
        }

        // barrier: iteration ends when the slowest env finishes
        let barrier_at = env_done_at.iter().copied().fold(clock, f64::max);
        let idle: f64 = env_done_at.iter().map(|&t| barrier_at - t).sum::<f64>()
            / n_envs as f64;
        agg.update_barrier_s += idle + t_update;
        clock = barrier_at + t_update;
    }

    let episodes = (iterations * n_envs) as f64;
    SimResult {
        cfg_envs: n_envs,
        cfg_ranks: cfg.n_ranks,
        total_cpus: n_envs * cfg.n_ranks,
        total_s: clock,
        breakdown: SimBreakdown {
            cfd_s: agg.cfd_s / episodes,
            io_s: agg.io_s / episodes,
            policy_s: agg.policy_s / episodes,
            update_barrier_s: agg.update_barrier_s / (iterations as f64),
        },
        disk_utilisation: disk_busy / clock.max(1e-12),
    }
}

#[allow(clippy::too_many_arguments)]
fn finish_period(
    heap: &mut BinaryHeap<Event>,
    periods_left: &mut [usize],
    env_done_at: &mut [f64],
    env: usize,
    now: f64,
    t_period: f64,
    calib: &Calibration,
    sigma: f64,
    mu_corr: f64,
    rng: &mut Rng,
    agg: &mut SimBreakdown,
) {
    periods_left[env] -= 1;
    if periods_left[env] == 0 {
        env_done_at[env] = now;
        return;
    }
    let jit = (mu_corr + sigma * rng.normal()).exp();
    let dt = (t_period + calib.t_policy) * jit;
    agg.cfd_s += t_period * jit;
    agg.policy_s += calib.t_policy * jit;
    heap.push(Event {
        time: now + dt,
        env,
        kind: EventKind::ComputeDone,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn cfg(envs: usize, ranks: usize, mode: IoMode) -> SimConfig {
        SimConfig {
            n_envs: envs,
            n_ranks: ranks,
            episodes_total: 300,
            io_mode: mode,
            seed: 42,
        }
    }

    #[test]
    fn deterministic() {
        let c = Calibration::paper_scale();
        let a = simulate_training(&c, &cfg(8, 1, IoMode::Baseline));
        let b = simulate_training(&c, &cfg(8, 1, IoMode::Baseline));
        assert_eq!(a.total_s, b.total_s);
    }

    #[test]
    fn more_envs_is_faster() {
        let c = Calibration::paper_scale();
        let t1 = simulate_training(&c, &cfg(1, 1, IoMode::Baseline)).total_s;
        let t4 = simulate_training(&c, &cfg(4, 1, IoMode::Baseline)).total_s;
        let t8 = simulate_training(&c, &cfg(8, 1, IoMode::Baseline)).total_s;
        assert!(t4 < t1);
        assert!(t8 < t4);
        // sublinear: efficiency < 1
        assert!(t4 > t1 / 4.0);
    }

    #[test]
    fn io_disabled_never_slower() {
        let c = Calibration::paper_scale();
        for envs in [1, 10, 40, 60] {
            let base = simulate_training(&c, &cfg(envs, 1, IoMode::Baseline)).total_s;
            let none = simulate_training(&c, &cfg(envs, 1, IoMode::InMemory)).total_s;
            let opt = simulate_training(&c, &cfg(envs, 1, IoMode::Optimized)).total_s;
            assert!(none <= base, "envs={envs}");
            assert!(opt <= base * 1.001, "envs={envs}");
        }
    }

    #[test]
    fn disk_saturates_at_many_envs() {
        let c = Calibration::paper_scale();
        let u10 = simulate_training(&c, &cfg(10, 1, IoMode::Baseline)).disk_utilisation;
        let u60 = simulate_training(&c, &cfg(60, 1, IoMode::Baseline)).disk_utilisation;
        assert!(u60 > 0.85, "disk util at 60 envs = {u60}");
        assert!(u10 < 0.5, "disk util at 10 envs = {u10}");
    }

    #[test]
    fn invariants_hold_over_random_configs() {
        let c = Calibration::paper_scale();
        prop::check("DES invariants", 25, |rng| {
            let envs = 1 + rng.below(64);
            let ranks = 1 + rng.below(8);
            let mode = match rng.below(3) {
                0 => IoMode::Baseline,
                1 => IoMode::Optimized,
                _ => IoMode::InMemory,
            };
            let r = simulate_training(
                &c,
                &SimConfig {
                    n_envs: envs,
                    n_ranks: ranks,
                    episodes_total: 60,
                    io_mode: mode,
                    seed: rng.next_u64(),
                },
            );
            if !(r.total_s.is_finite() && r.total_s > 0.0) {
                return Err("non-finite total".into());
            }
            if r.disk_utilisation > 1.0 + 1e-9 {
                return Err(format!("disk util {}", r.disk_utilisation));
            }
            // an episode can never run faster than its pure compute
            let floor = c.t_period_1rank * c.horizon as f64 * 0.5; // jitter slack
            if (r.total_s / (60f64 / envs as f64).ceil()) < floor {
                return Err("iteration faster than compute floor".into());
            }
            Ok(())
        });
    }
}

// ---------------------------------------------------------------------------
// Asynchronous-training variant (the paper's future-work ablation)
// ---------------------------------------------------------------------------

/// Simulate the asynchronous (barrier-free) training mode: environments
/// run episodes back-to-back, and a dedicated master core applies one
/// PPO update per arriving episode (FIFO); environments do NOT wait for
/// updates (bounded-stale parameters, A3C-style). The run ends when the
/// last update completes. Compare with [`simulate_training`] via
/// `drlfoam reproduce ablation`.
pub fn simulate_training_async(calib: &Calibration, cfg: &SimConfig) -> SimResult {
    let mut rng = Rng::new(cfg.seed ^ 0xA57);
    let n_envs = cfg.n_envs.max(1);
    let episodes_per_env = cfg.episodes_total.div_ceil(n_envs);
    let horizon = calib.horizon;

    let (bytes, io_cpu) = match cfg.io_mode {
        IoMode::Baseline => (calib.bytes_baseline, calib.t_io_cpu_baseline),
        IoMode::Optimized => (calib.bytes_optimized, calib.t_io_cpu_optimized),
        IoMode::InMemory => (0.0, 0.0),
    };
    let t_period = calib.t_period_1rank * calib.rank_model.period_factor(cfg.n_ranks);
    // per-episode update (single trajectory): epochs x ceil(horizon/mb)
    let t_update = calib.epochs as f64
        * horizon.div_ceil(calib.minibatch) as f64
        * calib.t_update_mb;

    let sigma = calib.period_jitter;
    let mu_corr = -0.5 * sigma * sigma;
    let ep_sigma = calib.episode_jitter;
    let ep_mu_corr = -0.5 * ep_sigma * ep_sigma;

    let mut agg = SimBreakdown::default();
    let mut disk_busy = 0.0f64;
    let mut disk_free_at = 0.0f64;
    let mut update_free_at = 0.0f64;

    // one global event loop over the whole run: per env, remaining
    // periods of the current episode + remaining episodes
    let mut heap: BinaryHeap<Event> = BinaryHeap::new();
    let mut periods_left = vec![horizon; n_envs];
    let mut episodes_left = vec![episodes_per_env; n_envs];
    let mut ep_factor = vec![1.0f64; n_envs];

    let mut draw_period = |rng: &mut Rng, agg: &mut SimBreakdown, f: f64| -> f64 {
        let jit = f * (mu_corr + sigma * rng.normal()).exp();
        agg.cfd_s += t_period * jit;
        agg.policy_s += calib.t_policy * jit;
        (t_period + calib.t_policy) * jit
    };

    for e in 0..n_envs {
        ep_factor[e] = (ep_mu_corr + ep_sigma * rng.normal()).exp();
        let dt = draw_period(&mut rng, &mut agg, ep_factor[e]);
        heap.push(Event { time: dt, env: e, kind: EventKind::ComputeDone });
    }

    let mut last_update_done = 0.0f64;
    while let Some(ev) = heap.pop() {
        let next_time = match ev.kind {
            EventKind::ComputeDone if bytes > 0.0 || io_cpu > 0.0 => {
                let ready = ev.time + io_cpu;
                let svc = bytes / calib.disk_bw;
                let begin = disk_free_at.max(ready);
                agg.io_s += io_cpu + (begin - ready) + svc;
                disk_free_at = begin + svc;
                disk_busy += svc;
                heap.push(Event { time: disk_free_at, env: ev.env, kind: EventKind::DiskDone });
                continue;
            }
            _ => ev.time,
        };
        // a period (incl. any exchange) finished at next_time
        periods_left[ev.env] -= 1;
        if periods_left[ev.env] == 0 {
            // episode complete: enqueue the update (env does not wait)
            let begin = update_free_at.max(next_time);
            update_free_at = begin + t_update;
            last_update_done = last_update_done.max(update_free_at);
            agg.update_barrier_s += t_update;
            episodes_left[ev.env] -= 1;
            if episodes_left[ev.env] == 0 {
                continue;
            }
            periods_left[ev.env] = horizon;
            ep_factor[ev.env] = (ep_mu_corr + ep_sigma * rng.normal()).exp();
        }
        let dt = draw_period(&mut rng, &mut agg, ep_factor[ev.env]);
        heap.push(Event { time: next_time + dt, env: ev.env, kind: EventKind::ComputeDone });
    }

    let makespan = last_update_done;
    let episodes = (episodes_per_env * n_envs) as f64;
    SimResult {
        cfg_envs: n_envs,
        cfg_ranks: cfg.n_ranks,
        total_cpus: n_envs * cfg.n_ranks,
        total_s: makespan,
        breakdown: SimBreakdown {
            cfd_s: agg.cfd_s / episodes,
            io_s: agg.io_s / episodes,
            policy_s: agg.policy_s / episodes,
            update_barrier_s: agg.update_barrier_s / episodes,
        },
        disk_utilisation: disk_busy / makespan.max(1e-12),
    }
}

#[cfg(test)]
mod async_tests {
    use super::*;

    fn cfg(envs: usize, mode: IoMode) -> SimConfig {
        SimConfig {
            n_envs: envs,
            n_ranks: 1,
            episodes_total: 600,
            io_mode: mode,
            seed: 9,
        }
    }

    #[test]
    fn async_no_slower_than_sync_without_io() {
        let c = Calibration::paper_scale();
        for envs in [4usize, 12, 30, 60] {
            let sync = simulate_training(&c, &cfg(envs, IoMode::InMemory)).total_s;
            let asyn = simulate_training_async(&c, &cfg(envs, IoMode::InMemory)).total_s;
            assert!(
                asyn <= sync * 1.02,
                "envs={envs}: async {asyn:.0}s vs sync {sync:.0}s"
            );
        }
    }

    #[test]
    fn async_removes_barrier_loss_at_scale() {
        let c = Calibration::paper_scale();
        let envs = 60;
        let sync = simulate_training(&c, &cfg(envs, IoMode::Optimized)).total_s;
        let asyn = simulate_training_async(&c, &cfg(envs, IoMode::Optimized)).total_s;
        // the sync barrier costs >= 10% at 60 envs (max of 60 lognormals)
        assert!(
            asyn < sync * 0.95,
            "async {asyn:.0}s not meaningfully faster than sync {sync:.0}s"
        );
    }

    #[test]
    fn async_deterministic() {
        let c = Calibration::paper_scale();
        let a = simulate_training_async(&c, &cfg(8, IoMode::Baseline)).total_s;
        let b = simulate_training_async(&c, &cfg(8, IoMode::Baseline)).total_s;
        assert_eq!(a, b);
    }
}
