//! Hybrid-parallelization planner: the paper's headline *search*.
//!
//! The paper's central result is not any single mechanism but the joint
//! optimization over hybrid configurations: deconstruct the framework,
//! benchmark the components, then pick the `(N_envs x N_ranks x I/O)`
//! layout that lifts 60-core parallel efficiency from ~49% to ~78%
//! (Table I, Figs 10-12). Rabault & Kuhnle (1906.10382) showed the
//! env-count axis alone saturates, which is exactly why the joint sweep
//! matters. This module performs that optimization against the
//! calibrated DES ([`super::des`]), with the rollout-scheduler barrier
//! ([`SyncPolicy`]) as a fourth axis the paper names as future work.
//!
//! [`search`] exhaustively enumerates feasible layouts
//! `(n_envs, ranks_per_env, sync, io)` with `n_envs * ranks_per_env <=
//! cores`, scores each via [`simulate_training`], and returns a ranked
//! [`PlanSet`] plus the Pareto front over *(wall-time, parallel
//! efficiency, mean staleness)* — not just the argmin, because async
//! layouts trade staleness for wall-time and that trade is the user's
//! call, not the planner's.
//!
//! Conventions:
//! * speedup/efficiency use the paper's global reference — the
//!   `{n_envs = 1, n_ranks = 1}` run under baseline I/O and a full
//!   barrier (the 225.2 h corner of Table I) — via
//!   [`crate::metrics::scaling`];
//! * every sync policy of a layout is scored on the IDENTICAL episode
//!   count: the smallest whole-per-env budget `>= episodes_total`
//!   (`ceil(episodes_total / n_envs) * n_envs`). The synchronous loop
//!   can only run whole iterations — that rounding is real cost, kept
//!   per the paper's fixed-budget methodology — but without a shared
//!   per-layout budget the partial/async loops (which consume exactly
//!   `episodes_total`) would beat the full barrier on phantom episodes
//!   rather than on scheduling (see `SimResult::episodes_run`);
//! * async layouts are charged one extra core — the DES models their
//!   updates on a dedicated master running concurrently with the envs,
//!   so feasibility uses `n_envs * n_ranks + 1 <= cores` and the
//!   efficiency denominator counts it (full/partial serialize the
//!   update on the envs' own time and get no such core);
//! * the scalar ranking multiplies wall time by
//!   `1 + staleness_weight * mean_staleness`
//!   ([`PlannerConfig::staleness_weight`]). The default weight encodes
//!   a strong on-policy preference, so the recommended layout matches
//!   the paper's synchronous framework unless an off-policy layout buys
//!   a large wall-time factor; weight 0 is the pure wall-clock argmin
//!   (the relaxed-barrier end of the axis wins at scale);
//! * `IoMode::InMemory` (the paper's I/O-*disabled* diagnostic bound)
//!   is excluded from the default sweep because a cluster deployment
//!   must actually move the exchange data; pass it in
//!   [`PlannerConfig::io_options`] to include it (`drlfoam train
//!   --layout auto` does, since the in-process loop really can skip
//!   the filesystem).
//!
//! CLI surfaces: `drlfoam plan --cores N` prints the ranked table and
//! writes `out/plan.csv`; `drlfoam train --layout auto` runs the search
//! against a measured-small calibration and applies the winner to the
//! live scheduler loop; `drlfoam reproduce plan` reproduces the paper's
//! optimal-config claim at 60 cores (~47x speedup, ~78% efficiency).

use anyhow::{Context, Result};

use crate::cluster::calib::Calibration;
use crate::cluster::des::{simulate_training, SimConfig};
use crate::coordinator::scheduler::SyncPolicy;
use crate::io_interface::IoMode;
use crate::metrics::scaling::{efficiency, speedup};
use crate::metrics::tables::{render_table, write_csv};

/// What the scalar ranking optimizes (`drlfoam plan --objective ...`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Staleness-weighted wall time (the default; see module docs).
    Time,
    /// Staleness-weighted `speedup * efficiency` — the knee of the
    /// scaling curve. Raw parallel efficiency alone would always crown
    /// the trivial single-core corner (efficiency is sub-linear in
    /// cores by definition); weighting by speedup rewards the largest
    /// layout that still scales well.
    Efficiency,
    /// Same score as [`Objective::Time`], but Pareto-front members rank
    /// ahead of every dominated layout.
    Pareto,
}

impl Objective {
    /// Parse a CLI/config string (trimmed, case-insensitive); the error
    /// lists the accepted values.
    pub fn parse(s: &str) -> Result<Objective> {
        match s.trim().to_ascii_lowercase().as_str() {
            "time" | "wall" | "wall-time" => Ok(Objective::Time),
            "efficiency" | "eff" => Ok(Objective::Efficiency),
            "pareto" => Ok(Objective::Pareto),
            _ => anyhow::bail!("unknown objective {s:?} (accepted: time, efficiency, pareto)"),
        }
    }

    /// Canonical name, inverse of [`Objective::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Time => "time",
            Objective::Efficiency => "efficiency",
            Objective::Pareto => "pareto",
        }
    }
}

/// The search space and scoring knobs for one [`search`] call.
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    /// Core budget: every layout satisfies `n_envs * n_ranks <= cores`.
    pub cores: usize,
    /// Total episode budget each layout is scored on (paper: 3000).
    pub episodes_total: usize,
    pub objective: Objective,
    /// Candidate MPI ranks per environment. Defaults to the paper's
    /// Table-I grid `{1, 2, 5}`.
    pub ranks_options: Vec<usize>,
    /// Candidate environment counts; `None` sweeps every feasible count
    /// `1..=cores/ranks`. `train --layout auto` pins this when the user
    /// passed `--envs` explicitly.
    pub env_options: Option<Vec<usize>>,
    /// Candidate scheduler barriers. `Partial { k }` is clamped to the
    /// layout's pool size; barrier options whose effective k collides
    /// with an earlier one are skipped for that layout (e.g.
    /// `partial:30` at 8 envs IS the full barrier). `Async` is never
    /// merged with `partial:1` — its dedicated-master schedule differs.
    pub sync_options: Vec<SyncPolicy>,
    /// Candidate exchange strategies (default: baseline + optimized;
    /// see the module docs for why in-memory is opt-in).
    pub io_options: Vec<IoMode>,
    /// Wall-time penalty per unit of mean parameter staleness in the
    /// scalar score (`t * (1 + w * staleness)`). 0 = pure wall time.
    pub staleness_weight: f64,
    /// Host topology (cores per host, index 0 = the coordinator's host;
    /// mirrors `--hosts host:cores,...`). `None` = one big SMP box.
    /// When set, a layout is feasible only if its rank groups pack onto
    /// the hosts first-fit without splitting a group
    /// ([`crate::exec::net::place_rank_groups`]), and every env placed
    /// off host 0 is charged the inter-node round trip
    /// ([`Calibration::t_net_rtt`]) in the DES.
    pub hosts: Option<Vec<usize>>,
    /// DES seed shared by every scored layout.
    pub seed: u64,
}

impl PlannerConfig {
    /// Paper-scale defaults for a given core budget (see field docs).
    pub fn new(cores: usize) -> Self {
        PlannerConfig {
            cores,
            episodes_total: 3000,
            objective: Objective::Time,
            ranks_options: vec![1, 2, 5],
            env_options: None,
            sync_options: vec![
                SyncPolicy::Full,
                SyncPolicy::Partial { k: 30 },
                SyncPolicy::Partial { k: 8 },
                SyncPolicy::Async,
            ],
            io_options: vec![IoMode::Baseline, IoMode::Optimized],
            staleness_weight: 0.5,
            hosts: None,
            seed: 1,
        }
    }
}

/// One scored layout: the configuration axes plus every DES-derived
/// metric the ranking and the Pareto front use.
#[derive(Clone, Debug)]
pub struct Plan {
    pub n_envs: usize,
    pub n_ranks: usize,
    /// Cores the layout occupies: `n_envs * n_ranks`, plus one for the
    /// dedicated update master under [`SyncPolicy::Async`] (the other
    /// policies serialize the update on the envs' own time).
    pub total_cpus: usize,
    /// Distinct hosts the first-fit placement uses (1 without a
    /// [`PlannerConfig::hosts`] topology).
    pub n_hosts: usize,
    pub sync: SyncPolicy,
    pub io_mode: IoMode,
    /// Simulated wall time (hours) for the layout's shared budget —
    /// `ceil(episodes_total / n_envs) * n_envs` episodes, identical
    /// across this layout's sync policies (see module docs).
    pub duration_h: f64,
    /// vs the global `{1 env, 1 rank, baseline, full}` reference.
    pub speedup: f64,
    /// `100 * speedup / total_cpus` (global single-CPU reference).
    pub efficiency_pct: f64,
    /// Mean parameter-version staleness (see `SimResult::mean_staleness`).
    pub mean_staleness: f64,
    /// Mean barrier idle seconds per update round.
    pub barrier_idle_s: f64,
    /// Shared-disk busy fraction (saturation diagnostic).
    pub disk_utilisation: f64,
    /// Member of the Pareto front over (time, efficiency, staleness).
    pub pareto: bool,
    /// Scalar ranking score under the configured objective (lower wins).
    pub score: f64,
}

/// Header of `out/plan.csv` (one [`Plan`] per row, ranked best-first).
pub const PLAN_CSV_HEADER: &str = "n_envs,n_ranks,total_cpus,n_hosts,sync,io,duration_h,\
                                   speedup,efficiency_pct,mean_staleness,barrier_idle_s,\
                                   disk_util_pct,pareto,score";

impl Plan {
    /// One `plan.csv` row, inverse of [`Plan::from_csv`] up to the
    /// printed precision.
    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{},{},{},{:.4},{:.3},{:.2},{:.4},{:.3},{:.2},{},{:.6}",
            self.n_envs,
            self.n_ranks,
            self.total_cpus,
            self.n_hosts,
            self.sync.name(),
            self.io_mode.name(),
            self.duration_h,
            self.speedup,
            self.efficiency_pct,
            self.mean_staleness,
            self.barrier_idle_s,
            100.0 * self.disk_utilisation,
            self.pareto as u8,
            self.score,
        )
    }

    /// Parse one `plan.csv` row (as split by
    /// [`crate::metrics::tables::parse_csv`]).
    pub fn from_csv(fields: &[String]) -> Result<Plan> {
        anyhow::ensure!(
            fields.len() == 14,
            "plan.csv row has {} fields, expected 14",
            fields.len()
        );
        let num = |i: usize| -> Result<f64> {
            fields[i]
                .trim()
                .parse::<f64>()
                .with_context(|| format!("plan.csv field {i} {:?} is not a number", fields[i]))
        };
        let int = |i: usize| -> Result<usize> {
            fields[i]
                .trim()
                .parse::<usize>()
                .with_context(|| format!("plan.csv field {i} {:?} is not an integer", fields[i]))
        };
        Ok(Plan {
            n_envs: int(0)?,
            n_ranks: int(1)?,
            total_cpus: int(2)?,
            n_hosts: int(3)?,
            sync: SyncPolicy::parse(&fields[4])?,
            io_mode: IoMode::parse(&fields[5])?,
            duration_h: num(6)?,
            speedup: num(7)?,
            efficiency_pct: num(8)?,
            mean_staleness: num(9)?,
            barrier_idle_s: num(10)?,
            disk_utilisation: num(11)? / 100.0,
            pareto: int(12)? != 0,
            score: num(13)?,
        })
    }
}

/// The ranked outcome of one [`search`] call.
#[derive(Clone, Debug)]
pub struct PlanSet {
    pub cores: usize,
    pub episodes_total: usize,
    pub objective: Objective,
    pub staleness_weight: f64,
    /// Duration of the global `{1 env, 1 rank, baseline, full}`
    /// reference run (hours) — the denominator of every speedup.
    pub reference_h: f64,
    /// Every feasible layout, best first.
    pub plans: Vec<Plan>,
}

impl PlanSet {
    /// The recommended layout (rank 1).
    pub fn best(&self) -> Option<&Plan> {
        self.plans.first()
    }

    /// The Pareto-front members, in ranking order.
    pub fn pareto_front(&self) -> Vec<&Plan> {
        self.plans.iter().filter(|p| p.pareto).collect()
    }

    /// Render the top `top` rows as a paper-style text table.
    pub fn render(&self, top: usize) -> String {
        let rows: Vec<Vec<String>> = self
            .plans
            .iter()
            .take(top)
            .enumerate()
            .map(|(i, p)| {
                vec![
                    (i + 1).to_string(),
                    p.n_envs.to_string(),
                    p.n_ranks.to_string(),
                    p.total_cpus.to_string(),
                    p.n_hosts.to_string(),
                    p.sync.name(),
                    p.io_mode.name().to_string(),
                    format!("{:.1}", p.duration_h),
                    format!("{:.1}", p.speedup),
                    format!("{:.1}", p.efficiency_pct),
                    format!("{:.2}", p.mean_staleness),
                    if p.pareto { "*".to_string() } else { String::new() },
                ]
            })
            .collect();
        render_table(
            &format!(
                "Allocation plan: {} cores, {} episodes, objective {} \
                 (staleness weight {}, reference {:.1} h; * = Pareto front over \
                 time/efficiency/staleness; {} layouts swept)",
                self.cores,
                self.episodes_total,
                self.objective.name(),
                self.staleness_weight,
                self.reference_h,
                self.plans.len()
            ),
            &[
                "#", "N_envs", "N_ranks", "N_cpus", "hosts", "sync", "io", "duration (h)",
                "speedup", "eff (%)", "staleness", "P",
            ],
            &rows,
        )
    }

    /// Write every ranked layout to `path` ([`PLAN_CSV_HEADER`] schema).
    pub fn write_csv(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let rows: Vec<String> = self.plans.iter().map(Plan::to_csv).collect();
        write_csv(path, PLAN_CSV_HEADER, &rows)
    }
}

/// `a` Pareto-dominates `b` over (wall time, efficiency, staleness).
fn dominates(a: &Plan, b: &Plan) -> bool {
    let no_worse = a.duration_h <= b.duration_h
        && a.efficiency_pct >= b.efficiency_pct
        && a.mean_staleness <= b.mean_staleness;
    let better = a.duration_h < b.duration_h
        || a.efficiency_pct > b.efficiency_pct
        || a.mean_staleness < b.mean_staleness;
    no_worse && better
}

fn mark_pareto(plans: &mut [Plan]) {
    let dominated: Vec<bool> = plans
        .iter()
        .map(|b| plans.iter().any(|a| dominates(a, b)))
        .collect();
    for (p, d) in plans.iter_mut().zip(dominated) {
        p.pareto = !d;
    }
}

fn scalar_score(objective: Objective, weight: f64, p: &Plan) -> f64 {
    let penalty = 1.0 + weight * p.mean_staleness;
    match objective {
        Objective::Time | Objective::Pareto => p.duration_h * penalty,
        // speedup-weighted efficiency (see Objective::Efficiency),
        // negated so that "lower score wins" holds for every objective
        Objective::Efficiency => -(p.speedup * p.efficiency_pct / penalty),
    }
}

/// Exhaustively sweep every feasible layout under `cfg.cores` and rank
/// them (see the module docs for the scoring conventions). Errors when
/// the budget cannot host a single environment at any candidate rank
/// count.
pub fn search(calib: &Calibration, cfg: &PlannerConfig) -> Result<PlanSet> {
    anyhow::ensure!(cfg.episodes_total >= 1, "need a positive episode budget");
    anyhow::ensure!(!cfg.io_options.is_empty(), "need at least one io mode");
    anyhow::ensure!(!cfg.sync_options.is_empty(), "need at least one sync policy");
    let min_ranks = cfg
        .ranks_options
        .iter()
        .copied()
        .filter(|&r| r >= 1)
        .min()
        .context("need at least one ranks-per-env candidate")?;
    anyhow::ensure!(
        cfg.cores >= min_ranks,
        "core budget {} cannot host a single environment: the smallest \
         rank allocation among {:?} needs {} cores per env",
        cfg.cores,
        cfg.ranks_options,
        min_ranks
    );

    if let Some(hosts) = &cfg.hosts {
        anyhow::ensure!(!hosts.is_empty(), "--hosts topology has no hosts");
        anyhow::ensure!(
            hosts.iter().all(|&c| c >= 1),
            "--hosts topology has a zero-core host"
        );
    }

    let des = |envs: usize,
               ranks: usize,
               io_mode: IoMode,
               sync: SyncPolicy,
               episodes: usize,
               remote_envs: usize| {
        simulate_training(
            calib,
            &SimConfig {
                n_envs: envs,
                n_ranks: ranks,
                episodes_total: episodes,
                io_mode,
                sync,
                remote_envs,
                seed: cfg.seed,
            },
        )
    };

    // the paper's global reference: Table I's 225.2 h corner (reused
    // below when the sweep enumerates the identical layout). A single
    // env always packs onto host 0 — the coordinator's — so the
    // reference never pays the inter-node term.
    let reference = des(1, 1, IoMode::Baseline, SyncPolicy::Full, cfg.episodes_total, 0);
    let reference_h = reference.total_hours();

    let mut ranks_options = cfg.ranks_options.clone();
    ranks_options.retain(|&r| r >= 1);
    ranks_options.sort_unstable();
    ranks_options.dedup();

    let mut plans = Vec::new();
    for &ranks in &ranks_options {
        if ranks > cfg.cores {
            continue;
        }
        let env_candidates: Vec<usize> = match &cfg.env_options {
            Some(list) => {
                let mut v: Vec<usize> = list
                    .iter()
                    .copied()
                    .filter(|&e| e >= 1 && e * ranks <= cfg.cores)
                    .collect();
                v.sort_unstable();
                v.dedup();
                v
            }
            None => (1..=(cfg.cores / ranks)).collect(),
        };
        for envs in env_candidates {
            // host topology: the rank groups must pack first-fit without
            // splitting a group; envs placed off host 0 pay the
            // inter-node round trip in the DES
            let (remote_envs, n_hosts) = match &cfg.hosts {
                Some(hosts) => match crate::exec::net::place_rank_groups(hosts, envs, ranks) {
                    Ok(placement) => {
                        let remote = placement.iter().filter(|&&h| h != 0).count();
                        let mut used: Vec<usize> = placement.clone();
                        used.sort_unstable();
                        used.dedup();
                        (remote, used.len().max(1))
                    }
                    // fits the core budget but not the topology
                    Err(_) => continue,
                },
                None => (0, 1),
            };
            // the shared per-layout budget: smallest whole-per-env count
            // >= episodes_total, so every sync policy of this layout
            // trains the identical number of episodes (the synchronous
            // loop can only run whole iterations)
            let budget = cfg.episodes_total.div_ceil(envs) * envs;
            for &io_mode in &cfg.io_options {
                let mut seen_k: Vec<(usize, bool)> = Vec::new();
                for &sync in &cfg.sync_options {
                    // the async DES runs its updates on a DEDICATED
                    // master core, concurrent with the envs (full and
                    // partial serialize the update on the envs' own
                    // time); charge that core against the budget and
                    // in the efficiency denominator
                    let master = usize::from(sync == SyncPolicy::Async);
                    if envs * ranks + master > cfg.cores {
                        continue;
                    }
                    // dedup schedule-equivalent options for this pool
                    // size (partial:k >= n IS the full barrier). Async
                    // is never merged: its dedicated-master schedule
                    // differs from partial:1/full even at equal k.
                    let key = (sync.effective_k(envs), master == 1);
                    if seen_k.contains(&key) {
                        continue;
                    }
                    seen_k.push(key);
                    let is_reference = envs == 1
                        && ranks == 1
                        && io_mode == IoMode::Baseline
                        && sync == SyncPolicy::Full;
                    let r = if is_reference {
                        reference.clone()
                    } else {
                        des(envs, ranks, io_mode, sync, budget, remote_envs)
                    };
                    let t = r.total_hours();
                    let cpus = r.total_cpus + master;
                    plans.push(Plan {
                        n_envs: envs,
                        n_ranks: ranks,
                        total_cpus: cpus,
                        n_hosts,
                        sync,
                        io_mode,
                        duration_h: t,
                        speedup: speedup(reference_h, t),
                        efficiency_pct: efficiency(reference_h, t, 1, cpus),
                        mean_staleness: r.mean_staleness,
                        barrier_idle_s: r.breakdown.barrier_idle_s,
                        disk_utilisation: r.disk_utilisation,
                        pareto: false,
                        score: 0.0,
                    });
                }
            }
        }
    }

    anyhow::ensure!(
        !plans.is_empty(),
        "no feasible layout under {} cores (env candidates {:?}, ranks {:?})",
        cfg.cores,
        cfg.env_options,
        ranks_options
    );
    mark_pareto(&mut plans);
    for p in &mut plans {
        p.score = scalar_score(cfg.objective, cfg.staleness_weight, p);
    }
    let pareto_first = cfg.objective == Objective::Pareto;
    plans.sort_by(|a, b| {
        let front = if pareto_first {
            b.pareto.cmp(&a.pareto)
        } else {
            std::cmp::Ordering::Equal
        };
        front
            .then(a.score.total_cmp(&b.score))
            .then(a.total_cpus.cmp(&b.total_cpus))
            .then(a.n_envs.cmp(&b.n_envs))
            .then(a.n_ranks.cmp(&b.n_ranks))
    });

    Ok(PlanSet {
        cores: cfg.cores,
        episodes_total: cfg.episodes_total,
        objective: cfg.objective,
        staleness_weight: cfg.staleness_weight,
        reference_h,
        plans,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(cores: usize) -> PlannerConfig {
        let mut c = PlannerConfig::new(cores);
        c.episodes_total = 48;
        c
    }

    #[test]
    fn objective_parse_round_trips_and_lists_accepted() {
        for o in [Objective::Time, Objective::Efficiency, Objective::Pareto] {
            assert_eq!(Objective::parse(o.name()).unwrap(), o);
        }
        assert_eq!(Objective::parse(" Wall-Time ").unwrap(), Objective::Time);
        let err = Objective::parse("fastest").unwrap_err().to_string();
        assert!(
            err.contains("time") && err.contains("efficiency") && err.contains("pareto"),
            "{err}"
        );
    }

    #[test]
    fn sweep_is_exhaustive_and_deduplicated() {
        let calib = Calibration::paper_scale();
        let set = search(&calib, &small_cfg(6)).unwrap();
        assert!(!set.plans.is_empty());
        for p in &set.plans {
            // async layouts are charged their dedicated update master
            let master = usize::from(p.sync == SyncPolicy::Async);
            assert_eq!(p.total_cpus, p.n_envs * p.n_ranks + master);
            assert!(p.total_cpus <= 6, "layout over budget in sweep");
            assert!(p.duration_h.is_finite() && p.duration_h > 0.0);
        }
        // no two plans may describe the same effective schedule (async
        // is a distinct schedule even at k = 1, hence the bool)
        let mut keys: Vec<(usize, usize, &'static str, usize, bool)> = set
            .plans
            .iter()
            .map(|p| {
                (
                    p.n_envs,
                    p.n_ranks,
                    p.io_mode.name(),
                    p.sync.effective_k(p.n_envs),
                    p.sync == SyncPolicy::Async,
                )
            })
            .collect();
        let n = keys.len();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), n, "duplicate effective schedules in sweep");
        // the async axis survives the sweep as its own schedule
        assert!(
            set.plans.iter().any(|p| p.sync == SyncPolicy::Async),
            "async layouts missing from the default sweep"
        );
    }

    #[test]
    fn host_topology_gates_packing_and_charges_the_round_trip() {
        let mut calib = Calibration::paper_scale();
        calib.t_net_rtt = 0.050;
        // two 3-core hosts: 6 cores total, but a 5-rank group fits nowhere
        let mut cfg = small_cfg(6);
        cfg.hosts = Some(vec![3, 3]);
        let set = search(&calib, &cfg).unwrap();
        assert!(
            set.plans.iter().all(|p| p.n_ranks != 5),
            "a 5-rank group cannot pack onto 3-core hosts"
        );
        // single-host layouts report 1 host; spilled layouts report 2
        // and are slower than the same layout planned without topology
        let spilled = set
            .plans
            .iter()
            .find(|p| p.n_hosts == 2 && p.sync == SyncPolicy::Full)
            .expect("some layout spans both hosts");
        assert!(spilled.n_envs * spilled.n_ranks > 3);
        assert!(set.plans.iter().any(|p| p.n_hosts == 1));
        let flat = search(&calib, &small_cfg(6)).unwrap();
        let twin = flat
            .plans
            .iter()
            .find(|p| {
                p.n_envs == spilled.n_envs
                    && p.n_ranks == spilled.n_ranks
                    && p.sync == spilled.sync
                    && p.io_mode == spilled.io_mode
            })
            .unwrap();
        assert_eq!(twin.n_hosts, 1);
        assert!(
            spilled.duration_h > twin.duration_h,
            "remote placement {:.4}h not slower than single-host {:.4}h",
            spilled.duration_h,
            twin.duration_h
        );
    }

    #[test]
    fn pareto_front_is_consistent() {
        let calib = Calibration::paper_scale();
        let set = search(&calib, &small_cfg(6)).unwrap();
        let front = set.pareto_front();
        assert!(!front.is_empty(), "empty Pareto front");
        // nothing on the front is dominated; everything off it is
        for p in &set.plans {
            let dominated = set.plans.iter().any(|a| dominates(a, p));
            assert_eq!(!dominated, p.pareto, "pareto flag wrong for {p:?}");
        }
        // the fastest layout is always on the front
        let fastest = set
            .plans
            .iter()
            .min_by(|a, b| a.duration_h.total_cmp(&b.duration_h))
            .unwrap();
        assert!(fastest.pareto, "fastest layout dominated?");
    }

    #[test]
    fn ranking_is_deterministic() {
        let calib = Calibration::paper_scale();
        let a = search(&calib, &small_cfg(5)).unwrap();
        let b = search(&calib, &small_cfg(5)).unwrap();
        let key = |s: &PlanSet| -> Vec<String> { s.plans.iter().map(Plan::to_csv).collect() };
        assert_eq!(key(&a), key(&b));
    }

    #[test]
    fn render_shows_the_winner_and_the_front_marker() {
        let calib = Calibration::paper_scale();
        let set = search(&calib, &small_cfg(4)).unwrap();
        let txt = set.render(5);
        assert!(txt.contains("N_envs"), "{txt}");
        assert!(txt.contains('*'), "no Pareto marker rendered:\n{txt}");
    }
}
