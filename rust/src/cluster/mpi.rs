//! CFD strong-scaling laws.
//!
//! Two distinct empirical facts from the paper are modelled separately
//! (they are inconsistent with a single curve — see EXPERIMENTS.md notes):
//!
//! 1. **Fig 7** (solver-only strong scaling): speedup 1.8 @ 2 ranks,
//!    saturating, efficiency < 20% @ 16 ranks. Modelled as
//!    `T(n)/T(1) = f + (1-f)/n + c (n-1)^a` (Amdahl + comm overhead).
//!
//! 2. **Table I absolute durations**: one *episode* is slower with more
//!    ranks (225.2 h -> 289.6 h -> 305.8 h for ranks 1/2/5 at one env),
//!    because every actuation period launches a fresh solver instance
//!    whose decompose/reconstruct/startup overhead grows with ranks and
//!    swamps the solve-time gain on a 16k-cell mesh. Modelled as a
//!    per-period launch overhead linear in ranks, fit to the three
//!    observed durations.

/// Amdahl + communication-overhead law for the solver itself (Fig 7).
#[derive(Clone, Copy, Debug)]
pub struct MpiScaling {
    /// serial fraction
    pub f: f64,
    /// communication coefficient
    pub c: f64,
    /// communication exponent
    pub a: f64,
}

impl Default for MpiScaling {
    fn default() -> Self {
        // Fit to Fig 7: eff(2) ~ 0.9, eff(16) < 0.2, saturating in between.
        MpiScaling {
            f: 0.05,
            c: 0.022,
            a: 1.0,
        }
    }
}

impl MpiScaling {
    /// Normalised runtime T(n)/T(1).
    pub fn runtime_frac(&self, n_ranks: usize) -> f64 {
        let n = n_ranks as f64;
        self.f + (1.0 - self.f) / n + self.c * (n - 1.0).powf(self.a)
    }

    /// Solver-only strong-scaling speedup `T(1)/T(n)` — the Fig 7 T_1
    /// curve (~1.8x at 2 ranks, saturating beyond).
    ///
    /// ```
    /// use drlfoam::cluster::MpiScaling;
    /// let m = MpiScaling::default();
    /// assert!((m.speedup(1) - 1.0).abs() < 1e-12);
    /// assert!(m.speedup(2) > 1.6 && m.speedup(2) < 2.0); // Fig 7: ~1.8x
    /// ```
    pub fn speedup(&self, n_ranks: usize) -> f64 {
        1.0 / self.runtime_frac(n_ranks)
    }

    /// Solver-only parallel efficiency `speedup(n)/n` (fraction, not
    /// percent) — the Fig 7 efficiency curve, below 20% at 16 ranks.
    ///
    /// ```
    /// use drlfoam::cluster::MpiScaling;
    /// let m = MpiScaling::default();
    /// assert!(m.efficiency(2) > 0.8);
    /// assert!(m.efficiency(16) < 0.2); // Fig 7: the 16-rank collapse
    /// ```
    pub fn efficiency(&self, n_ranks: usize) -> f64 {
        self.speedup(n_ranks) / n_ranks as f64
    }
}

/// Per-actuation-period cost factor for the *coupled* framework:
/// `T_period(ranks) / T_period(1)`, including the per-instance launch
/// overhead. Fit to Table I single-env durations
/// (1: 225.2 h, 2: 289.6 h, 5: 305.8 h per 3000 episodes).
#[derive(Clone, Copy, Debug)]
pub struct RankPeriodModel {
    /// solver law (gain part)
    pub solver: MpiScaling,
    /// launch overhead as a fraction of the 1-rank period: b0 + b1 * n
    pub launch_b0: f64,
    pub launch_b1: f64,
}

impl Default for RankPeriodModel {
    fn default() -> Self {
        // Solve for (b0, b1) from the paper's observed period factors:
        //   factor(2) = 289.6/225.2 = 1.286
        //   factor(5) = 305.8/225.2 = 1.358
        // factor(n) = runtime_frac(n) + b0 + b1 n   (n > 1; factor(1) = 1)
        let solver = MpiScaling::default();
        let f2 = 289.6 / 225.2 - solver.runtime_frac(2);
        let f5 = 305.8 / 225.2 - solver.runtime_frac(5);
        let b1 = (f5 - f2) / 3.0;
        let b0 = f2 - 2.0 * b1;
        RankPeriodModel {
            solver,
            launch_b0: b0,
            launch_b1: b1,
        }
    }
}

impl RankPeriodModel {
    pub fn period_factor(&self, n_ranks: usize) -> f64 {
        if n_ranks <= 1 {
            return 1.0;
        }
        self.solver.runtime_frac(n_ranks) + self.launch_b0 + self.launch_b1 * n_ranks as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_shape() {
        let m = MpiScaling::default();
        assert!((m.speedup(1) - 1.0).abs() < 1e-12);
        let s2 = m.speedup(2);
        assert!(s2 > 1.6 && s2 < 2.0, "speedup(2) = {s2}");
        assert!(m.efficiency(2) > 0.8);
        assert!(m.efficiency(16) < 0.2, "eff(16) = {}", m.efficiency(16));
        // saturation: gains shrink
        assert!(m.speedup(8) - m.speedup(4) < m.speedup(4) - m.speedup(2));
    }

    #[test]
    fn speedup_bounded_by_ranks() {
        let m = MpiScaling::default();
        for n in 1..=32 {
            assert!(m.speedup(n) <= n as f64 + 1e-9);
            assert!(m.speedup(n) > 0.0);
        }
    }

    #[test]
    fn table1_period_factors_recovered() {
        let rm = RankPeriodModel::default();
        assert!((rm.period_factor(1) - 1.0).abs() < 1e-12);
        assert!((rm.period_factor(2) - 289.6 / 225.2).abs() < 1e-6);
        assert!((rm.period_factor(5) - 305.8 / 225.2).abs() < 1e-6);
        // multi-rank stays slower than single-rank on this mesh (the
        // paper's core finding about CFD parallelisation)
        for n in 2..=16 {
            assert!(rm.period_factor(n) > 1.0, "factor({n})");
        }
    }
}
