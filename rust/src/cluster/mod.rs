//! Cluster discrete-event simulator (DES) and the allocation planner
//! built on top of it.
//!
//! This machine has ONE core (repro band: hardware gate), so the paper's
//! 60-core scaling tables cannot be re-measured directly. Following the
//! substitution rule in DESIGN.md section 2, we simulate the cluster: a
//! discrete-event model of the multi-environment training framework whose
//! per-component costs are either *measured* on this machine (CFD period,
//! policy apply, PPO minibatch, exchange bytes — see `calibrate`) or
//! *fit to the paper's own measurements* (MPI rank scaling, episode
//! jitter, shared-disk bandwidth — each documented in [`calib`]).
//!
//! The DES reproduces the *shape* of Tables I-II and Figs 7-12: who wins,
//! where the efficiency cliffs fall, and the crossovers between hybrid
//! configurations. [`planner`] then closes the paper's headline loop: it
//! sweeps every feasible `(n_envs, ranks_per_env, sync, io)` layout under
//! a core budget, scores each with the DES, and ranks them — the search
//! that lifts 60-core parallel efficiency from ~49% to ~78% (Table I,
//! Figs 10-12).

pub mod calib;
pub mod des;
pub mod mpi;
pub mod planner;

pub use calib::Calibration;
pub use des::{simulate_training, SimBreakdown, SimConfig, SimResult};
pub use mpi::MpiScaling;
pub use planner::{search, Objective, Plan, PlanSet, PlannerConfig};
