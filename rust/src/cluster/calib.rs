//! DES calibration: where every constant comes from.
//!
//! Two presets:
//! * [`Calibration::paper_scale`] — absolute costs taken from the paper's
//!   own measurements (4.5 min/episode at 1 rank -> 2.704 s/period;
//!   5.0 MB baseline / 1.2 MB optimized exchange). Used by
//!   `drlfoam reproduce ...` so Tables I/II come out in comparable hours.
//! * [`Calibration::from_measured`] — per-component costs measured on this
//!   machine by `drlfoam calibrate` (saved to out/calib.json). Used by the
//!   DES-vs-real shadow validation (rust/tests/sim_vs_real.rs).
//!
//! Fitted (not measured) constants, each documented at the field:
//! episode jitter, shared-disk bandwidth, and the MPI scaling laws in
//! [`super::mpi`].
//!
//! Paper artefacts these presets feed: Table I / Fig 8-10 (absolute
//! durations + breakdown), Table II / Figs 11-12 (the exchange-volume
//! and CPU-cost constants per [`crate::io_interface::IoMode`]), and the
//! planner's 60-core optimum (`drlfoam reproduce plan`).

use anyhow::Result;

use crate::cluster::mpi::RankPeriodModel;
use crate::util::json::{self, Json};

#[derive(Clone, Debug)]
pub struct Calibration {
    /// wall seconds per actuation period, single-rank CFD
    pub t_period_1rank: f64,
    /// lognormal sigma of per-period time (measured CFD step noise)
    pub period_jitter: f64,
    /// lognormal sigma of per-EPISODE time across envs (FIT: this is what
    /// produces the paper's barrier losses — multi-env efficiency ~90% @
    /// 2 envs, ~86% @ 4, ~79% @ 12, ~78% @ 30 — because the iteration
    /// barrier waits for the slowest of N episode draws)
    pub episode_jitter: f64,
    /// policy apply (serving) per actuation period, seconds
    pub t_policy: f64,
    /// one PPO minibatch update, seconds
    pub t_update_mb: f64,
    /// PPO epochs per iteration (training-loop constant)
    pub epochs: usize,
    /// minibatch size (from the manifest)
    pub minibatch: usize,
    /// samples per episode (actuation periods; paper: 100)
    pub horizon: usize,
    /// exchange volume per period, bytes written+read, by mode
    pub bytes_baseline: f64,
    pub bytes_optimized: f64,
    /// CPU-side serialize/parse cost per exchange, seconds, by mode
    pub t_io_cpu_baseline: f64,
    pub t_io_cpu_optimized: f64,
    /// shared-disk bandwidth, bytes/s (FIT to the paper's N_envs > 30
    /// baseline cliff: 30 envs x 5 MB / 2.7 s ~ 55 MB/s saturation point)
    pub disk_bw: f64,
    /// coordinator↔agent socket round-trip, seconds (measured by
    /// `crate::exec::net::measure_rtt` when a socket transport is live;
    /// 0 = single-host, no inter-node term). The DES charges each
    /// remotely-placed env one round trip per actuation period.
    pub t_net_rtt: f64,
    /// rank-dependent period cost model (fit to Table I, see mpi.rs)
    pub rank_model: RankPeriodModel,
}

impl Calibration {
    /// Paper-scale preset (see module docs): absolute costs from the
    /// paper's own single-core measurements, so Table I/II come out in
    /// comparable hours.
    ///
    /// ```
    /// use drlfoam::cluster::Calibration;
    /// // 225.2 h / 3000 episodes / 100 periods ≈ 2.70 s per period
    /// let c = Calibration::paper_scale();
    /// assert!((c.t_period_1rank - 2.7024).abs() < 1e-3);
    /// ```
    pub fn paper_scale() -> Self {
        // 225.2 h / 3000 episodes / 100 periods = 2.7024 s per period
        let t_period = 225.2 * 3600.0 / 3000.0 / 100.0;
        Calibration {
            t_period_1rank: t_period,
            period_jitter: 0.03,
            episode_jitter: 0.11,
            t_policy: 0.010,
            t_update_mb: 0.020,
            epochs: 4,
            minibatch: 64,
            horizon: 100,
            bytes_baseline: 5.0e6,
            bytes_optimized: 1.2e6,
            t_io_cpu_baseline: 0.060,
            t_io_cpu_optimized: 0.004,
            disk_bw: 60.0e6,
            t_net_rtt: 0.0,
            rank_model: RankPeriodModel::default(),
        }
    }

    /// Scale the *measured* per-component costs of this machine into a
    /// calibration (keeps fitted constants from the paper preset, scaled
    /// so disk saturation happens at the same env count relative to the
    /// period time).
    pub fn from_measured(
        t_period: f64,
        t_policy: f64,
        t_update_mb: f64,
        bytes_baseline: f64,
        bytes_optimized: f64,
        t_io_cpu_baseline: f64,
        t_io_cpu_optimized: f64,
        horizon: usize,
    ) -> Self {
        let paper = Calibration::paper_scale();
        // keep the saturation point: bw such that 30 envs saturate
        let disk_bw = 30.0 * bytes_baseline / t_period;
        Calibration {
            t_period_1rank: t_period,
            t_policy,
            t_update_mb,
            bytes_baseline,
            bytes_optimized,
            t_io_cpu_baseline,
            t_io_cpu_optimized,
            disk_bw,
            horizon,
            ..paper
        }
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("t_period_1rank", json::num(self.t_period_1rank)),
            ("period_jitter", json::num(self.period_jitter)),
            ("episode_jitter", json::num(self.episode_jitter)),
            ("t_policy", json::num(self.t_policy)),
            ("t_update_mb", json::num(self.t_update_mb)),
            ("epochs", json::num(self.epochs as f64)),
            ("minibatch", json::num(self.minibatch as f64)),
            ("horizon", json::num(self.horizon as f64)),
            ("bytes_baseline", json::num(self.bytes_baseline)),
            ("bytes_optimized", json::num(self.bytes_optimized)),
            ("t_io_cpu_baseline", json::num(self.t_io_cpu_baseline)),
            ("t_io_cpu_optimized", json::num(self.t_io_cpu_optimized)),
            ("disk_bw", json::num(self.disk_bw)),
            ("t_net_rtt", json::num(self.t_net_rtt)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let paper = Calibration::paper_scale();
        Ok(Calibration {
            t_period_1rank: j.get("t_period_1rank")?.as_f64()?,
            period_jitter: j.get("period_jitter")?.as_f64()?,
            episode_jitter: j.get("episode_jitter")?.as_f64()?,
            t_policy: j.get("t_policy")?.as_f64()?,
            t_update_mb: j.get("t_update_mb")?.as_f64()?,
            epochs: j.get("epochs")?.as_usize()?,
            minibatch: j.get("minibatch")?.as_usize()?,
            horizon: j.get("horizon")?.as_usize()?,
            bytes_baseline: j.get("bytes_baseline")?.as_f64()?,
            bytes_optimized: j.get("bytes_optimized")?.as_f64()?,
            t_io_cpu_baseline: j.get("t_io_cpu_baseline")?.as_f64()?,
            t_io_cpu_optimized: j.get("t_io_cpu_optimized")?.as_f64()?,
            disk_bw: j.get("disk_bw")?.as_f64()?,
            // absent in calib.json files written before the socket
            // transports existed — default to the single-host 0
            t_net_rtt: j
                .get("t_net_rtt")
                .ok()
                .map(|v| v.as_f64())
                .transpose()?
                .unwrap_or(0.0),
            rank_model: paper.rank_model,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_period_matches_validation_study() {
        let c = Calibration::paper_scale();
        // 4.5 min/episode at 100 periods
        assert!((c.t_period_1rank * 100.0 / 60.0 - 4.5).abs() < 0.01);
    }

    #[test]
    fn json_roundtrip() {
        let c = Calibration::paper_scale();
        let j = c.to_json();
        let c2 = Calibration::from_json(&j).unwrap();
        assert_eq!(c2.t_period_1rank, c.t_period_1rank);
        assert_eq!(c2.disk_bw, c.disk_bw);
        assert_eq!(c2.epochs, c.epochs);
    }

    #[test]
    fn json_without_net_rtt_loads_with_zero_default() {
        // calib.json written before the socket transports existed
        let mut j = Calibration::paper_scale().to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("t_net_rtt");
        }
        let c = Calibration::from_json(&j).unwrap();
        assert_eq!(c.t_net_rtt, 0.0);
    }

    #[test]
    fn measured_preserves_saturation_point() {
        let c = Calibration::from_measured(0.3, 1e-3, 2e-3, 6e5, 1.5e5, 5e-3, 5e-4, 50);
        // 30 envs x bytes / period ~ disk_bw by construction
        let sat = 30.0 * c.bytes_baseline / c.t_period_1rank;
        assert!((sat / c.disk_bw - 1.0).abs() < 1e-9);
    }
}
